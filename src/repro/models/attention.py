"""GQA attention with global / sliding-window / chunked-local modes.

Backends:
  naive   — full [s, s] score materialization (oracle; smoke shapes only).
  blocked — memory-efficient XLA-level tiling (the dry-run/default backend):
            * global causal: q-block × kv-block online-softmax scans
            * sliding window: exact per-q-block KV slices (linear memory)
            * chunked-local: chunks folded into batch, causal within chunk
  pallas  — kernels.ops.flash_attention (TPU target; interpret-mode on CPU).

Decode uses a unified ring-buffer KV cache: slot = position % cache_len with
absolute positions stored alongside for mask reconstruction — one layout
covers global, sliding-window and chunked layers (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import layers
from repro.parallel.axes import gather_fsdp, shard

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnSettings:
    backend: str = "blocked"     # naive | blocked | pallas
    q_block: int = 512
    kv_block: int = 1024
    # GQA head sharding for sequence paths: when kv_heads doesn't divide the
    # model axis but n_heads does, repeat K/V up to H heads so attention
    # shards by q-head instead of replicating across the axis (EXPERIMENTS
    # §Perf iteration 1: removes per-layer [b,s,d] all-gathers). None = auto.
    repeat_kv: Optional[bool] = None
    # ZeRO-3 gather-on-use: all-gather FSDP-sharded weights at each use
    # instead of psum-ing activation partials (§Perf iteration 2).
    gather_weights: bool = False
    # Paged decode: emit per-logical-block attention mass ([b, max_blocks],
    # softmax weight summed within each block, averaged over heads) in the
    # attn aux dict — the signal the serving engine's block-granular
    # retention policy (MemoryPlan.kv_retain) ranks blocks by.
    track_mass: bool = False


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "norm": layers.rmsnorm_init(d, dt),
        "wq": layers.dense_init(kq, d, cfg.n_heads * hd, dt),
        "wk": layers.dense_init(kk, d, cfg.n_kv_heads * hd, dt),
        "wv": layers.dense_init(kv, d, cfg.n_kv_heads * hd, dt),
        "wo": layers.dense_init(ko, cfg.n_heads * hd, d, dt),
    }


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

def _mask(qpos, kpos, blk: BlockSpec):
    """qpos [..., sq], kpos [..., skv] -> bool [..., sq, skv]."""
    q = qpos[..., :, None].astype(jnp.int32)
    k = kpos[..., None, :].astype(jnp.int32)
    m = (k <= q) & (k >= 0)
    if blk.window is not None:
        m &= k > q - blk.window
    if blk.chunk is not None:
        m &= (k // blk.chunk) == (q // blk.chunk)
    return m


# ---------------------------------------------------------------------------
# Sequence attention backends
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, mask):
    """q [b,sq,K,G,hd], k/v [b,skv,K,hd], mask [b,sq,skv] -> [b,sq,K,G,hd]."""
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    s = layers.einsum_f32("bqkgh,bskh->bkgqs", q, k) * scale
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = layers.einsum_f32("bkgqs,bskh->bqkgh", p, v)
    return o.astype(q.dtype)


def _naive(q, k, v, qpos, kpos, blk):
    return _sdpa(q, k, v, _mask(qpos, kpos, blk))


def _blocked_causal(q, k, v, qpos, kpos, blk: BlockSpec, set_: AttnSettings):
    """Online-softmax blocked causal attention (global layers)."""
    b, s, K, G, hd = q.shape
    qb = min(set_.q_block, s)
    kb = min(set_.kv_block, s)
    nq, nk = -(-s // qb), -(-s // kb)
    pad_q, pad_k = nq * qb - s, nk * kb - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad_q)), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad_k)), constant_values=-1)
    scale = 1.0 / np.sqrt(hd)

    qs = q.reshape(b, nq, qb, K, G, hd)
    qps = qpos.reshape(b, nq, qb)
    ks = k.reshape(b, nk, kb, K, hd)
    vs = v.reshape(b, nk, kb, K, hd)
    kps = kpos.reshape(b, nk, kb)

    def per_qblock(q_i, qp_i):
        # q_i [b, qb, K, G, hd]; scan over kv blocks with running (m, l, acc)
        m0 = jnp.full((b, qb, K, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qb, K, G), jnp.float32)
        a0 = jnp.zeros((b, qb, K, G, hd), jnp.float32)

        @functools.partial(jax.checkpoint,
                           policy=jax.checkpoint_policies.nothing_saveable)
        def scan_body(carry, inp):
            m, l, acc = carry
            k_j, v_j, kp_j = inp
            sij = layers.einsum_f32("bqkgh,bskh->bqkgs", q_i, k_j) * scale
            msk = _mask(qp_i, kp_j, blk)
            sij = jnp.where(msk[:, :, None, None, :], sij, NEG_INF)
            m_new = jnp.maximum(m, sij.max(axis=-1))
            p = jnp.exp(sij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + layers.einsum_f32(
                "bqkgs,bskh->bqkgh", p, v_j)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            scan_body, (m0, l0, a0),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0),
             jnp.moveaxis(kps, 1, 0)))
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q_i.dtype)

    out = jax.lax.map(lambda args: jax.checkpoint(per_qblock)(*args),
                      (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(qps, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * qb, K, G, hd)
    return out[:, :s]


def _blocked_window(q, k, v, qpos, kpos, blk: BlockSpec, set_: AttnSettings):
    """Exact sliding-window attention: per-q-block KV slice of w + qb."""
    b, s, K, G, hd = q.shape
    w = blk.window
    qb = min(set_.q_block, s)
    nq = -(-s // qb)
    pad_q = nq * qb - s
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad_q)), constant_values=-1)
    # Left-pad KV by w so slice [i*qb, i*qb + w + qb) is always in range.
    kp = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    kpp = jnp.pad(kpos, ((0, 0), (w, 0)), constant_values=-1)
    span = w + qb

    @jax.checkpoint  # flash-style backward: recompute probs per q-block
    def per_qblock(i):
        q_i = jax.lax.dynamic_slice_in_dim(q, i * qb, qb, axis=1)
        qp_i = jax.lax.dynamic_slice_in_dim(qpos, i * qb, qb, axis=1)
        k_i = jax.lax.dynamic_slice_in_dim(kp, i * qb, span, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(vp, i * qb, span, axis=1)
        kp_i = jax.lax.dynamic_slice_in_dim(kpp, i * qb, span, axis=1)
        return _sdpa(q_i, k_i, v_i, _mask(qp_i, kp_i, blk))

    out = jax.lax.map(per_qblock, jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 1).reshape(b, nq * qb, K, G, hd)
    return out[:, :s]


def _chunked(q, k, v, qpos, kpos, blk: BlockSpec, set_: AttnSettings):
    """Chunked-local attention: fold chunks into batch, causal within."""
    b, s, K, G, hd = q.shape
    c = blk.chunk
    if s <= c:
        return _blocked_causal(q, k, v, qpos, kpos,
                               dataclasses.replace(blk, chunk=None), set_)
    pad = (-s) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad)), constant_values=-1)
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
        out = _chunked(q, k, v, qpos, kpos, blk, set_)
        return out[:, :s]
    nc = s // c
    fold = lambda t: t.reshape((b * nc, c) + t.shape[2:])
    out = _blocked_causal(fold(q), fold(k), fold(v), fold(qpos), fold(kpos),
                          dataclasses.replace(blk, chunk=None), set_)
    return out.reshape(b, s, K, G, hd)


def _seq_attention(q, k, v, qpos, kpos, blk, set_: AttnSettings):
    if set_.backend == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, qpos, kpos,
                                    window=blk.window, chunk=blk.chunk)
    if set_.backend == "naive":
        return _naive(q, k, v, qpos, kpos, blk)
    if blk.window is not None:
        return _blocked_window(q, k, v, qpos, kpos, blk, set_)
    if blk.chunk is not None:
        return _chunked(q, k, v, qpos, kpos, blk, set_)
    return _blocked_causal(q, k, v, qpos, kpos, blk, set_)


# ---------------------------------------------------------------------------
# Ring-buffer KV cache
# ---------------------------------------------------------------------------

def cache_init(cfg: ModelConfig, blk: BlockSpec, batch: int, context: int,
               dtype=jnp.bfloat16):
    L = blk.cache_len(context)
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, L, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, L, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, L), -1, jnp.int32),
    }


def cache_spec(cfg: ModelConfig, blk: BlockSpec, batch: int, context: int,
               dtype=jnp.bfloat16):
    """ShapeDtypeStruct version of cache_init (dry-run, no allocation)."""
    L = blk.cache_len(context)
    hd = cfg.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, L, cfg.n_kv_heads, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, L, cfg.n_kv_heads, hd), dtype),
        "pos": jax.ShapeDtypeStruct((batch, L), jnp.int32),
    }


def _cache_from_prefill(k, v, positions, blk: BlockSpec, context: int):
    """Build a ring cache holding the last cache_len positions of a prefill.

    Always emits the FULL cache_len(context) ring: a prompt shorter than the
    ring pads the empty slots with pos=-1 (masked). Without the padding a
    short-prompt prefill would hand decode a ring of length prompt_len whose
    slot = pos % prompt_len mapping evicts live context early (a global
    layer's ring must only wrap at cache_len); it also gives every sequence
    the same cache shapes, which is what lets the serving engine write any
    prefill into a pool slot (runtime.serve_step.write_cache_slot)."""
    L = blk.cache_len(context)
    k_t, v_t, p_t = k[:, -L:], v[:, -L:], positions[:, -L:]
    pad = L - k_t.shape[1]
    if pad > 0:
        # prefill positions start at 0, so occupied slots are already at
        # pos % L = 0..p-1; empty tail slots stay invalid
        k_t = jnp.pad(k_t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_t = jnp.pad(v_t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        p_t = jnp.pad(p_t, ((0, 0), (0, pad)), constant_values=-1)
    # Ring layout: slot = pos % L. For contiguous positions that's a roll.
    shift = p_t[0, 0] % L  # uniform across batch (packed sequences)
    return {
        "k": jnp.roll(k_t, shift, axis=1),
        "v": jnp.roll(v_t, shift, axis=1),
        "pos": jnp.roll(p_t, shift, axis=1),
    }


def _decode_attend(q, cache, blk: BlockSpec, positions,
                   return_probs: bool = False):
    """q [b,1,K,G,hd], cache k/v [b,L,K,hd]; positions [b]."""
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    s = layers.einsum_f32("bqkgh,bskh->bkgqs", q, cache["k"]) * scale
    msk = _mask(positions[:, None], cache["pos"], blk)   # [b, 1, L]
    s = jnp.where(msk[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = layers.einsum_f32("bkgqs,bskh->bqkgh", p, cache["v"])
    if return_probs:
        return o.astype(q.dtype), p
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged KV pool (serving decode)
# ---------------------------------------------------------------------------
#
# A paged layer cache is {"kb": [n_blocks, block, K, hd], "vb": ...,
# "pos": [n_blocks, block]} — storage is a POOL of fixed-size position
# blocks shared by every sequence, and each sequence's logical layout is a
# block table [max_blocks] mapping logical block j (positions j*block ..
# (j+1)*block - 1) to a physical block id (-1 = not yet allocated).
# Physical block 0 is the SCRATCH block: inactive decode lanes (table all
# -1) read and write it harmlessly, so one batched decode serves any pool
# occupancy with a single compile. Only full-context layers page; short
# windowed/chunked rings stay per-lane (see runtime.serve_step).
#
# QUANTIZED pools (MemoryPlan.kv_quant) additionally carry per-token
# per-head f32 absmax scales {"ks": [n_blocks, block, K], "vs": ...}; the
# pool is SELF-DESCRIBING — kb dtype int8 => "int8", uint8 => "int4"
# (two nibbles per byte, offset +8) — so every read/write path picks the
# codec from the cache itself and can never disagree with the layout
# init_paged_pool allocated. Scales are per-token rows, so appending a
# token to a block never rescales entries already written (block-granular
# absmax would force a lossy requantize on every tail write).

KV_QUANT_MAX = {"int8": 127.0, "int4": 7.0}


def paged_quant_kind(cache) -> str:
    """Storage codec of a paged layer cache, read off its own leaves."""
    if "ks" not in cache:
        return "none"
    return "int8" if cache["kb"].dtype == jnp.int8 else "int4"


def quantize_kv(x, kind: str):
    """Encode K/V rows for pool storage: x [..., hd] fp ->
    (q [..., hd] int8 | [..., hd//2] uint8, scale [...] f32). Per-row
    (token, head) absmax scales: |dequant - x| <= scale / 2 per element."""
    if kind == "none":
        return x, None
    qmax = KV_QUANT_MAX[kind]
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / qmax
    q = jnp.round(xf / jnp.maximum(scale, 1e-30)[..., None])
    q = jnp.clip(q, -qmax, qmax)
    if kind == "int8":
        return q.astype(jnp.int8), scale
    nib = (q + 8.0).astype(jnp.uint8)            # 1..15 (0 unused)
    lo, hi = nib[..., 0::2], nib[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8), scale


def dequantize_kv(q, scale, kind: str, dtype=jnp.bfloat16):
    """Decode pool-stored K/V rows back to fp (inverse of quantize_kv)."""
    if kind == "none":
        return q
    if kind == "int8":
        return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
    lo = (q & 0xF).astype(jnp.int32) - 8
    hi = (q >> 4).astype(jnp.int32) - 8
    full = jnp.stack([lo, hi], axis=-1).reshape(*q.shape[:-1],
                                                q.shape[-1] * 2)
    return (full.astype(jnp.float32) * scale[..., None]).astype(dtype)


def is_paged_cache(cache) -> bool:
    return isinstance(cache, dict) and "kb" in cache


def _paged_write(cache, block_tables, k1, v1, pos1):
    """Write one token per lane (k1/v1 [b,K,hd], pos1 [b]) into the pool at
    (table[pos // block], pos % block). Lanes with no block mapped (table
    entry -1) and INERT lanes (pos1 < 0 — a padding row the engine carries
    at full decode width while the lane is empty or mid-chunk-prefill) land
    in the scratch block with pos -1, so they can never clobber live KV."""
    n_blocks, bsz = cache["pos"].shape
    m_blocks = block_tables.shape[1]
    live = pos1 >= 0
    safe_pos = jnp.where(live, pos1, 0)
    lb = jnp.minimum(safe_pos // bsz, m_blocks - 1)
    off = safe_pos % bsz
    phys = jnp.take_along_axis(block_tables, lb[:, None], axis=1)[:, 0]
    phys = jnp.where(live & (phys >= 0), phys, 0)        # scratch fallback
    kind = paged_quant_kind(cache)
    kq, ks = quantize_kv(k1, kind)
    vq, vs = quantize_kv(v1, kind)
    out = {
        "kb": cache["kb"].at[phys, off].set(kq.astype(cache["kb"].dtype)),
        "vb": cache["vb"].at[phys, off].set(vq.astype(cache["vb"].dtype)),
        "pos": cache["pos"].at[phys, off].set(jnp.where(live, pos1, -1)),
    }
    if kind != "none":
        out["ks"] = cache["ks"].at[phys, off].set(ks)
        out["vs"] = cache["vs"].at[phys, off].set(vs)
    return out


def _paged_gather(cache, block_tables):
    """Gather each lane's blocks into a contiguous virtual ring
    ([b, max_blocks*block, ...]): unassigned table entries read the scratch
    block with their positions masked to -1, so downstream masking treats
    them as empty slots."""
    b, m_blocks = block_tables.shape
    bsz = cache["pos"].shape[1]
    safe = jnp.where(block_tables >= 0, block_tables, 0)
    pos = jnp.where(block_tables[..., None] >= 0, cache["pos"][safe], -1)
    kind = paged_quant_kind(cache)
    k, v = cache["kb"][safe], cache["vb"][safe]  # [b, mB, bs, K, hd']
    if kind != "none":
        k = dequantize_kv(k, cache["ks"][safe], kind)
        v = dequantize_kv(v, cache["vs"][safe], kind)
    return {
        "k": k.reshape(b, m_blocks * bsz, *k.shape[3:]),
        "v": v.reshape(b, m_blocks * bsz, *v.shape[3:]),
        "pos": pos.reshape(b, m_blocks * bsz),
    }


def _paged_write_chunk(cache, block_tables, k, v, positions):
    """Write a prompt chunk per lane (k/v [b, C, K, hd], positions [b, C],
    -1 = padding) into the pool through the block tables. Padding entries
    and entries whose logical block is unmapped land in the scratch block
    with pos -1, so nothing real can be clobbered and nothing stale can
    pass the mask."""
    n_blocks, bsz = cache["pos"].shape
    m_blocks = block_tables.shape[1]
    valid = positions >= 0
    safe_pos = jnp.where(valid, positions, 0)
    lb = jnp.clip(safe_pos // bsz, 0, m_blocks - 1)          # [b, C]
    phys = jnp.take_along_axis(block_tables, lb, axis=1)
    phys = jnp.where(valid & (phys >= 0), phys, 0)           # scratch
    off = safe_pos % bsz
    kind = paged_quant_kind(cache)
    kq, ks = quantize_kv(k, kind)
    vq, vs = quantize_kv(v, kind)
    out = {
        "kb": cache["kb"].at[phys, off].set(kq.astype(cache["kb"].dtype)),
        "vb": cache["vb"].at[phys, off].set(vq.astype(cache["vb"].dtype)),
        "pos": cache["pos"].at[phys, off].set(
            jnp.where(valid, positions, -1)),
    }
    if kind != "none":
        out["ks"] = cache["ks"].at[phys, off].set(ks)
        out["vs"] = cache["vs"].at[phys, off].set(vs)
    return out


def _chunk_append(q, k, v, cache, blk: BlockSpec, positions, block_tables,
                  settings: AttnSettings = AttnSettings()):
    """Chunked prefill: append a prompt chunk to an EXISTING cache and
    attend over history + chunk — exactly the chunk's slice of a full
    prefill, so interleaving chunks with decode ticks changes scheduling
    but never tokens. Paged layers go through the fused flash-prefill
    kernel when settings.backend == "pallas" (write + attend in one pass,
    O(chunk x block) tiles, quantize-on-write in-kernel) and otherwise
    scatter through the block table and attend over the gathered virtual
    ring (the jnp oracle: O(chunk x context) scores plus, for quantized
    pools, a dequantized fp copy of the context — the transient the tiled
    kernel exists to avoid); per-lane rings attend over concat(ring,
    chunk) and then keep only the last cache_len positions (slot = pos % L
    stays collision-free because the kept span is at most L consecutive
    positions)."""
    b, C = positions.shape
    valid = positions >= 0
    if is_paged_cache(cache):
        assert block_tables is not None, \
            "paged cache needs block_tables for chunked prefill"
        if settings.backend == "pallas":
            from repro.kernels import ops as kops
            quant = paged_quant_kind(cache)
            out = kops.paged_prefill_attention(
                q, k, v, cache["kb"], cache["vb"], cache["pos"],
                block_tables, positions, window=blk.window, chunk=blk.chunk,
                k_scales=(cache["ks"] if quant != "none" else None),
                v_scales=(cache["vs"] if quant != "none" else None))
            o, ppos, kb, vb = out[:4]
            new_cache = {"kb": kb, "vb": vb, "pos": ppos}
            if quant != "none":
                new_cache["ks"], new_cache["vs"] = out[4], out[5]
            return o, new_cache
        new_cache = _paged_write_chunk(cache, block_tables, k, v, positions)
        virt = _paged_gather(new_cache, block_tables)
        o = _sdpa(q, virt["k"], virt["v"],
                  _mask(positions, virt["pos"], blk))
        return o, new_cache
    L = cache["pos"].shape[1]
    kcat = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)], axis=1)
    vcat = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], axis=1)
    pcat = jnp.concatenate([cache["pos"], jnp.where(valid, positions, -1)],
                           axis=1)
    o = _sdpa(q, kcat, vcat, _mask(positions, pcat, blk))
    # ring write-back: only positions inside the final window survive
    # (a chunk longer than the ring would otherwise wrap onto itself)
    row_end = jnp.max(jnp.where(valid, positions, -1), axis=1, keepdims=True)
    keep = valid & (positions > row_end - L)
    slot = jnp.where(keep, positions % L, L)                 # L -> dropped
    bidx = jnp.arange(b)[:, None]
    new_cache = {
        "k": cache["k"].at[bidx, slot].set(k.astype(cache["k"].dtype),
                                           mode="drop"),
        "v": cache["v"].at[bidx, slot].set(v.astype(cache["v"].dtype),
                                           mode="drop"),
        "pos": cache["pos"].at[bidx, slot].set(positions, mode="drop"),
    }
    return o, new_cache


def _paged_decode(q, cache, blk: BlockSpec, pos1, k1, v1, block_tables,
                  settings: AttnSettings):
    """One decode step against the paged pool: scatter the new K/V entry,
    then attend through the block table — via the Pallas paged kernel
    (interpret-mode off-TPU; quantized pools dequant IN-kernel on the
    block-table DMA path) or the jnp gather fallback. Returns
    (o, new_cache, mass or None): `mass` [b, max_blocks] is each logical
    block's softmax share, emitted when settings.track_mass."""
    new_cache = _paged_write(cache, block_tables, k1, v1, pos1)
    b, m_blocks = block_tables.shape
    bsz = cache["pos"].shape[1]
    if settings.backend == "pallas":
        from repro.kernels import ops as kops
        quant = paged_quant_kind(new_cache)
        out = kops.paged_decode_attention(
            q[:, 0], new_cache["kb"], new_cache["vb"], new_cache["pos"],
            block_tables, pos1, window=blk.window, chunk=blk.chunk,
            k_scales=(new_cache["ks"] if quant != "none" else None),
            v_scales=(new_cache["vs"] if quant != "none" else None),
            return_mass=settings.track_mass)
        if settings.track_mass:
            o, mass = out
            return o[:, None], new_cache, mass
        return out[:, None], new_cache, None
    virt = _paged_gather(new_cache, block_tables)
    if settings.track_mass:
        o, p = _decode_attend(q, virt, blk, pos1, return_probs=True)
        # p [b, K, G, 1, mB*bs]: average heads, fold positions into blocks
        mass = p.mean(axis=(1, 2))[:, 0].reshape(b, m_blocks, bsz).sum(-1)
        return o, new_cache, mass
    return _decode_attend(q, virt, blk, pos1), new_cache, None


# ---------------------------------------------------------------------------
# Block entry point
# ---------------------------------------------------------------------------

def attn_apply(params, cfg: ModelConfig, blk: BlockSpec, x, positions,
               cache=None, decode: bool = False, context: int = 0,
               settings: AttnSettings = AttnSettings(), block_tables=None):
    """x [b, s, d]; positions [b, s] (s=1 for decode). `block_tables`
    [b, max_blocks] routes decode through a paged pool cache (see the
    paged-KV section above) when the layer's cache is paged.

    Returns (y [b, s, d], new_cache or None, aux dict). `aux` carries
    "attn_mass" [b, max_blocks] on paged decode when settings.track_mass
    (the block-retention signal); empty otherwise.
    """
    b, s, d = x.shape
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    G = cfg.q_group
    h = layers.rmsnorm(params["norm"], x, cfg.norm_eps)
    wq, wk, wv, wo = (params["wq"], params["wk"], params["wv"], params["wo"])
    if settings.gather_weights:
        wq = gather_fsdp(wq, None, "q_w")
        wk = gather_fsdp(wk, None, "kv_w")
        wv = gather_fsdp(wv, None, "kv_w")
        wo = gather_fsdp(wo, "q_w", None)
    q = layers.matmul(h, wq).reshape(b, s, K, G, hd)
    k = layers.matmul(h, wk).reshape(b, s, K, hd)
    v = layers.matmul(h, wv).reshape(b, s, K, hd)
    use_repeat = settings.repeat_kv
    if use_repeat is None:                       # auto (DESIGN.md §4)
        from repro.parallel import axes as pax
        mesh = pax.current_mesh()
        msize = mesh.shape.get("model", 1) if mesh is not None else 1
        use_repeat = (G > 1 and msize > 1 and K % msize != 0
                      and (K * G) % msize == 0)
    appending = (not decode and cache is not None
                 and not isinstance(cache, str))
    use_repeat = use_repeat and G > 1 and not decode and not appending
    if not use_repeat:
        # kv-head sharding (replicates over model when K doesn't divide it)
        q = shard(q, "batch", "seq", "kv_heads", None, None)
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
    if blk.rope:
        q = layers.apply_rope(q.reshape(b, s, K * G, hd), positions,
                              cfg.rope_theta).reshape(b, s, K, G, hd)
        k = layers.apply_rope(k, positions, cfg.rope_theta)

    aux = {}
    if decode:
        assert cache is not None and s == 1
        pos1 = positions.reshape(b)              # accept [b] or [b, 1]
        if is_paged_cache(cache):
            assert block_tables is not None, \
                "paged cache needs block_tables at decode"
            o, new_cache, mass = _paged_decode(q, cache, blk, pos1, k[:, 0],
                                               v[:, 0], block_tables,
                                               settings)
            if mass is not None:
                aux["attn_mass"] = mass
        else:
            L = cache["pos"].shape[1]
            # inert rows (pos1 < 0) drop their ring write entirely — slot L
            # is out of range and mode="drop" discards it
            slot = jnp.where(pos1 >= 0, pos1 % L, L)
            bidx = jnp.arange(b)
            new_cache = {
                "k": cache["k"].at[bidx, slot].set(k[:, 0], mode="drop"),
                "v": cache["v"].at[bidx, slot].set(v[:, 0], mode="drop"),
                "pos": cache["pos"].at[bidx, slot].set(pos1, mode="drop"),
            }
            o = _decode_attend(q, new_cache, blk, pos1)
    elif appending:
        # chunked prefill: a real cache on the sequence path means "append
        # this chunk to what the earlier chunks already wrote"
        o, new_cache = _chunk_append(q, k, v, cache, blk, positions,
                                     block_tables, settings)
    else:
        kpos = positions
        if use_repeat:
            kr = jnp.repeat(k, G, axis=2)        # kv index h -> h // G
            vr = jnp.repeat(v, G, axis=2)
            qh = q.reshape(b, s, K * G, 1, hd)
            qh = shard(qh, "batch", "seq", "heads", None, None)
            kr = shard(kr, "batch", "seq", "heads", None)
            vr = shard(vr, "batch", "seq", "heads", None)
            o = _seq_attention(qh, kr, vr, positions, kpos, blk, settings)
            o = o.reshape(b, s, K, G, hd)
        else:
            o = _seq_attention(q, k, v, positions, kpos, blk, settings)
        new_cache = (_cache_from_prefill(k, v, positions, blk, context)
                     if cache == "build" else None)

    o = o.reshape(b, s, cfg.n_heads * hd)
    y = layers.matmul(o, wo)
    return shard(y, "batch", "seq", "embed"), new_cache, aux
