"""Model assembly: embedding -> scan(pattern units) -> tail -> norm -> head.

The depth pattern (configs.base: unit × repeats + tail) is the lax.scan unit:
parameters and caches are *stacked over repeats* per unit position, so
heterogeneous patterns (gemma3 5:1, griffin rec-rec-attn, xLSTM 7:1) scan
with uniform bodies. The runtime injects remat around the unit body.

All mixers follow the delta convention: they return the residual increment.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, MLSTM, RGLRU, SLSTM, MLP_DENSE,
                                MLP_MOE, BlockSpec, ModelConfig)
from repro.models import attention, layers, moe, recurrent
from repro.parallel.axes import shard


@dataclasses.dataclass(frozen=True)
class ModelSettings:
    attn: attention.AttnSettings = attention.AttnSettings()
    mlstm_backend: Optional[str] = None     # None => kernels.ops default
    mlstm_chunk: int = 128
    build_cache: bool = False               # prefill returns a filled cache
    scan_layers: bool = True                # False: unroll (exact HLO cost
                                            # accounting — roofline/analysis)
    embed_onehot: bool = True               # matmul embedding lookup — on a
                                            # vocab-sharded table this avoids
                                            # the gather's involuntary full
                                            # resharding (§Perf iter 3;
                                            # gemma3 train T_mem −20%)
    moe_group: int = 2048                   # MoE routing group size —
                                            # dispatch FLOPs/bytes ∝ group


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    mult = 2 if layers.is_glu(cfg.activation) else 1
    ki, ko = jax.random.split(key)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "norm": layers.rmsnorm_init(d, dt),
        "wi": layers.dense_init(ki, d, mult * f, dt),
        "wo": layers.dense_init(ko, f, d, dt),
    }


def mlp_apply(params, cfg: ModelConfig, x, gather_weights: bool = False):
    from repro.parallel.axes import gather_fsdp
    wi, wo = params["wi"], params["wo"]
    if gather_weights:
        wi = gather_fsdp(wi, None, "mlp")
        wo = gather_fsdp(wo, "mlp", None)
    h = layers.rmsnorm(params["norm"], x, cfg.norm_eps)
    up = layers.matmul(h, wi)
    up = shard(up, "batch", "seq", "mlp_act")
    if layers.is_glu(cfg.activation):
        gate, val = jnp.split(up, 2, axis=-1)
        act = layers.glu_combine(cfg.activation, gate, val)
    else:
        act = layers.ACTIVATIONS[cfg.activation](up)
    y = layers.matmul(act, wo)
    return shard(y, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Block = mixer + channel mixer
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, blk: BlockSpec):
    km, kc = jax.random.split(key)
    p: Dict[str, Any] = {}
    if blk.mixer == ATTN:
        p["mixer"] = attention.attn_init(km, cfg)
    elif blk.mixer == MLSTM:
        p["mixer"] = recurrent.mlstm_init(km, cfg)
    elif blk.mixer == SLSTM:
        p["mixer"] = recurrent.slstm_init(km, cfg)
    elif blk.mixer == RGLRU:
        p["mixer"] = recurrent.rglru_init(km, cfg)
    if blk.mlp == MLP_DENSE:
        p["mlp"] = mlp_init(kc, cfg)
    elif blk.mlp == MLP_MOE:
        p["mlp"] = moe.moe_init(kc, cfg)
    return p


def block_cache_init(cfg: ModelConfig, blk: BlockSpec, batch: int,
                     context: int, abstract: bool = False):
    if blk.mixer == ATTN:
        fn = attention.cache_spec if abstract else attention.cache_init
        return fn(cfg, blk, batch, context)
    if blk.mixer == MLSTM:
        return recurrent.mlstm_state_init(cfg, batch, abstract)
    if blk.mixer == SLSTM:
        return recurrent.slstm_state_init(cfg, batch, abstract)
    if blk.mixer == RGLRU:
        return recurrent.rglru_state_init(cfg, batch, abstract)
    raise ValueError(blk.mixer)


def block_apply(params, cfg: ModelConfig, blk: BlockSpec, x, positions,
                cache=None, decode: bool = False, context: int = 0,
                settings: ModelSettings = ModelSettings(),
                block_tables=None):
    """Returns (x', new_cache, aux)."""
    aux = _zero_aux()
    building = settings.build_cache and not decode and cache is None
    if blk.mixer == ATTN:
        cache_arg = cache if cache is not None else ("build" if building
                                                     else None)
        delta, new_cache, attn_aux = attention.attn_apply(
            params["mixer"], cfg, blk, x, positions, cache=cache_arg,
            decode=decode, context=context, settings=settings.attn,
            block_tables=block_tables)
        aux = {**aux, **attn_aux}
    else:
        if building:  # prefill: recurrent blocks start from zero state
            cache = block_cache_init(cfg, blk, x.shape[0], context)
        if blk.mixer == MLSTM:
            delta, new_cache = recurrent.mlstm_apply(
                params["mixer"], cfg, x, state=cache, decode=decode,
                backend=settings.mlstm_backend, chunk=settings.mlstm_chunk,
                positions=positions)
        elif blk.mixer == SLSTM:
            delta, new_cache = recurrent.slstm_apply(
                params["mixer"], cfg, x, state=cache, decode=decode,
                positions=positions)
        elif blk.mixer == RGLRU:
            delta, new_cache = recurrent.rglru_apply(
                params["mixer"], cfg, x, state=cache, decode=decode,
                positions=positions)
        else:
            raise ValueError(blk.mixer)
        if decode and cache is not None:
            # full-width serving ticks include INERT rows (position -1:
            # empty lanes, lanes mid-chunk-prefill) — their pad-token
            # step must not advance the lane's recurrent state
            live = positions[:, 0] >= 0
            new_cache = jax.tree.map(
                lambda nw, old: jnp.where(
                    live.reshape((-1,) + (1,) * (nw.ndim - 1)), nw, old),
                new_cache, cache)
    x = x + delta
    if blk.mlp == MLP_DENSE:
        x = x + mlp_apply(params["mlp"], cfg, x,
                          gather_weights=settings.attn.gather_weights)
    elif blk.mlp == MLP_MOE:
        delta, aux = moe.moe_apply(params["mlp"], cfg, x,
                                   group_size=settings.moe_group)
        x = x + delta
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Parameter / cache trees
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, 4)
    params: Dict[str, Any] = {"embed": layers.embed_init(keys[0], cfg)}

    def stacked_init(pos_key, blk):
        ks = jax.random.split(pos_key, max(cfg.repeats, 1))
        return jax.vmap(lambda k_: block_init(k_, cfg, blk))(ks)

    unit_keys = jax.random.split(keys[1], max(len(cfg.unit), 1))
    params["units"] = [stacked_init(unit_keys[i], blk)
                       for i, blk in enumerate(cfg.unit)]
    tail_keys = jax.random.split(keys[2], max(len(cfg.tail), 1))
    params["tail"] = [block_init(tail_keys[i], cfg, blk)
                      for i, blk in enumerate(cfg.tail)]
    params["final_norm"] = layers.rmsnorm_init(cfg.d_model,
                                               jnp.dtype(cfg.param_dtype))
    if not cfg.tie_embeddings:
        params["head"] = {"table": (jax.random.normal(
            keys[3], (cfg.padded_vocab_size, cfg.d_model), jnp.float32)
            * layers.INIT_STD).astype(jnp.dtype(cfg.param_dtype))}
    return params


def init_cache(cfg: ModelConfig, batch: int, context: int,
               abstract: bool = False):
    """Cache tree mirroring the params layout (stacked over repeats)."""
    def stacked(blk):
        one = block_cache_init(cfg, blk, batch, context, abstract=True)
        stack = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.repeats,) + s.shape, s.dtype),
            one)
        if abstract:
            return stack
        return jax.tree.map(lambda s: _materialize(s), stack)

    def _materialize(s):
        if s.dtype == jnp.int32:   # position buffers start invalid
            return jnp.full(s.shape, -1, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    cache = {"units": [stacked(blk) for blk in cfg.unit],
             "tail": []}
    for blk in cfg.tail:
        one = block_cache_init(cfg, blk, batch, context, abstract=True)
        cache["tail"].append(
            one if abstract else jax.tree.map(_materialize, one))
    return cache


# ---------------------------------------------------------------------------
# Staged forward pieces (pipeline runtime): embed | unit stack | tail + head
# ---------------------------------------------------------------------------

def _zero_aux():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}


def unit_stack_forward(units_params, cfg: ModelConfig, x, pos, *,
                       settings: ModelSettings = ModelSettings(),
                       context: int = 0,
                       unit_wrapper: Callable = lambda f: f):
    """Forward through a slice of the stacked unit pattern (train path, no
    caches) — the 1F1B pipeline-stage body. `units_params` is the params
    layout of params["units"] (one tree per unit position, each stacked on a
    leading repeats dim, here the stage's own slice). Returns (x, aux_sum).
    """
    ctx = context or x.shape[1]

    def unit_body(x, unit_params):
        aux_sum = _zero_aux()
        for i, blk in enumerate(cfg.unit):
            x, _, aux = block_apply(unit_params[i], cfg, blk, x, pos,
                                    cache=None, decode=False, context=ctx,
                                    settings=settings)
            aux_sum = {k: aux_sum[k] + aux.get(k, 0) for k in aux_sum}
        return x, aux_sum

    unit_body = unit_wrapper(unit_body)

    def scan_body(carry, xs):
        x, aux_acc = carry
        x, aux = unit_body(x, list(xs))
        return (x, {k: aux_acc[k] + aux.get(k, 0) for k in aux_acc}), ()

    (x, aux_acc), _ = jax.lax.scan(scan_body, (x, _zero_aux()),
                                   tuple(units_params))
    return x, aux_acc


def tail_head_forward(params, cfg: ModelConfig, x, pos, *,
                      settings: ModelSettings = ModelSettings(),
                      context: int = 0):
    """The post-pipeline remainder: tail blocks -> final norm -> LM head.
    Returns (logits, aux_sum)."""
    ctx = context or x.shape[1]
    aux_acc = _zero_aux()
    for i, blk in enumerate(cfg.tail):
        x, _, aux = block_apply(params["tail"][i], cfg, blk, x, pos,
                                cache=None, decode=False, context=ctx,
                                settings=settings)
        aux_acc = {k: aux_acc[k] + aux.get(k, 0) for k in aux_acc}
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return layers.lm_head(head, cfg, x), aux_acc


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def apply(params, cfg: ModelConfig, tokens, *, positions=None,
          prefix_embeds=None, cache=None, decode: bool = False,
          settings: ModelSettings = ModelSettings(), context: int = 0,
          unit_wrapper: Callable = lambda f: f, logits_last_only: bool = False,
          block_tables=None):
    """Forward pass.

    tokens [b, s] (s=1 for decode); positions [b] for decode, [b, s]
    absolute positions for a mid-prompt chunk (else implied arange);
    prefix_embeds [b, p, d] for modality-stub archs; block_tables
    [b, max_blocks] maps each sequence's logical KV blocks to physical
    blocks of a paged pool cache (serving decode; -1 = unassigned).
    Returns (logits, new_cache_or_None, aux).
    """
    b = tokens.shape[0]
    x = layers.embed_lookup(params["embed"], cfg, tokens,
                            onehot=settings.embed_onehot)
    if prefix_embeds is not None and not decode:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    if decode:
        assert positions is not None
        pos = positions[:, None]                      # [b, 1]
    elif positions is not None:
        # explicit absolute positions [b, s] (chunked prefill appends a
        # mid-prompt slice; -1 marks padding)
        assert prefix_embeds is None
        pos = positions
    else:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    ctx = context or s

    zero_aux = _zero_aux()
    if decode and block_tables is not None and settings.attn.track_mass:
        # per-block attention mass, summed over layers (relative heat is
        # what the retention policy ranks on)
        zero_aux["attn_mass"] = jnp.zeros(
            (b, block_tables.shape[1]), jnp.float32)
    want_cache = decode or settings.build_cache
    have_cache = cache is not None

    def unit_body(x, unit_params, unit_caches):
        new_caches = []
        aux_sum = dict(zero_aux)
        for i, blk in enumerate(cfg.unit):
            c = unit_caches[i] if unit_caches is not None else None
            x, nc, aux = block_apply(unit_params[i], cfg, blk, x, pos,
                                     cache=c, decode=decode, context=ctx,
                                     settings=settings,
                                     block_tables=block_tables)
            new_caches.append(nc)
            aux_sum = {k: aux_sum[k] + aux.get(k, 0) for k in aux_sum}
        return x, new_caches, aux_sum

    unit_body = unit_wrapper(unit_body)

    if cfg.unit and settings.scan_layers and not have_cache \
            and not want_cache:
        # cache-free training forward: the same unit-stack scan the 1F1B
        # pipeline stages run (one implementation, so pipeline parity can
        # never drift from the sequential path)
        x, aux_acc = unit_stack_forward(params["units"], cfg, x, pos,
                                        settings=settings, context=ctx,
                                        unit_wrapper=unit_wrapper)
        new_unit_caches = None
    elif cfg.unit and settings.scan_layers:
        def scan_body(carry, xs):
            x, aux_acc = carry
            unit_params = xs[:len(cfg.unit)]
            unit_caches = (list(xs[len(cfg.unit):]) if have_cache else None)
            x, new_caches, aux = unit_body(x, list(unit_params), unit_caches)
            aux_acc = {k: aux_acc[k] + aux.get(k, 0) for k in aux_acc}
            ys = tuple(new_caches) if want_cache else ()
            return (x, aux_acc), ys

        xs = tuple(params["units"])
        if have_cache:
            xs = xs + tuple(cache["units"])
        (x, aux_acc), ys = jax.lax.scan(scan_body, (x, dict(zero_aux)), xs)
        new_unit_caches = list(ys) if want_cache else None
    elif cfg.unit:
        # Unrolled path: python loop over repeats (exact per-layer HLO cost).
        aux_acc = dict(zero_aux)
        collected = []
        for r in range(cfg.repeats):
            unit_params = [jax.tree.map(lambda a: a[r], t)
                           for t in params["units"]]
            unit_caches = ([jax.tree.map(lambda a: a[r], t)
                            for t in cache["units"]] if have_cache else None)
            x, new_caches, aux = unit_body(x, unit_params, unit_caches)
            aux_acc = {k: aux_acc[k] + aux.get(k, 0) for k in aux_acc}
            if want_cache:
                collected.append(new_caches)
        if want_cache and collected:
            new_unit_caches = [
                jax.tree.map(lambda *leaves: jnp.stack(leaves),
                             *[collected[r][i] for r in range(cfg.repeats)])
                for i in range(len(cfg.unit))]
        else:
            new_unit_caches = None
    else:
        aux_acc = dict(zero_aux)
        new_unit_caches = None

    new_tail_caches = []
    for i, blk in enumerate(cfg.tail):
        c = cache["tail"][i] if have_cache else None
        x, nc, aux = block_apply(params["tail"][i], cfg, blk, x, pos,
                                 cache=c, decode=decode, context=ctx,
                                 settings=settings,
                                 block_tables=block_tables)
        new_tail_caches.append(nc)
        aux_acc = {k: aux_acc[k] + aux.get(k, 0) for k in aux_acc}

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logits_last_only and not decode:
        x = x[:, -1:]
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = layers.lm_head(head, cfg, x)

    new_cache = ({"units": new_unit_caches, "tail": new_tail_caches}
                 if want_cache else None)
    return logits, new_cache, aux_acc
