"""Mixture-of-Experts channel mixer: top-k router + capacity-factor dispatch.

TPU adaptation (DESIGN.md §5): dispatch/combine are dense one-hot einsums
(GShard/Switch style) — on the MXU these outperform gather/scatter routing
used by GPU implementations. The dispatch tensor [b, s, E, C] is the MoE
"shuffle data" in WSMC terms: its transient footprint scales with the
capacity factor and is exactly what pushes MoE archs into the Expanding
categories; the planner controls it with microbatching.

Expert weights are 3-D [E, d, f]: FSDP over d ("embed_w"), TP over f ("mlp");
the EP strategy re-maps "experts" -> "model" instead (parallel/sharding.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.parallel.axes import shard


def moe_init(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    mult = 2 if layers.is_glu(cfg.activation) else 1
    kr, ki, ko = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "norm": layers.rmsnorm_init(d, dt),
        "router": layers.dense_init(kr, d, E, jnp.float32),
        "wi": (jax.random.normal(ki, (E, d, mult * f), jnp.float32)
               * layers.INIT_STD).astype(dt),
        "wo": (jax.random.normal(ko, (E, f, d), jnp.float32)
               * layers.INIT_STD).astype(dt),
    }


def moe_apply(params, cfg: ModelConfig, x, group_size: int = 2048):
    """x [b, s, d] -> (y [b, s, d], aux {lb_loss, z_loss}).

    Tokens are routed in fixed-size groups (GShard): capacity — and the
    dispatch transient — stays O(group_size), not O(seq). Decode steps
    (s=1) group across the batch instead.
    """
    b0, s0, d = x.shape
    if s0 == 1 and b0 > 1:                       # decode: batch is the group
        y, aux = _moe_grouped(params, cfg, x.reshape(1, b0, d),
                              min(group_size, b0))
        return y.reshape(b0, s0, d), aux
    g = min(group_size, s0)
    pad = (-s0) % g
    if pad:
        xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        y, aux = moe_apply(params, cfg, xp, group_size)
        return y[:, :s0], aux
    n_g = s0 // g
    y, aux = _moe_grouped(params, cfg,
                          x.reshape(b0 * n_g, g, d) if n_g > 1 else x, g)
    return y.reshape(b0, s0, d), aux


def _moe_grouped(params, cfg: ModelConfig, x, group: int):
    """x [rows, group, d] — one independent routing group per row."""
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(-(-s * k * cfg.capacity_factor // E)))

    h = layers.rmsnorm(params["norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,de->bse", h.astype(jnp.float32),
                        params["router"])                       # [b, s, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)               # [b, s, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)            # renormalize

    # Capacity assignment with choice priority (Switch): earlier choices
    # claim slots first; cumulative per-expert counts shared across choices.
    dispatch = jnp.zeros((b, s, E, C), h.dtype)
    combine = jnp.zeros((b, s, E, C), jnp.float32)
    counts = jnp.zeros((b, E), jnp.int32)
    for choice in range(k):
        e_onehot = jax.nn.one_hot(gate_idx[..., choice], E,
                                  dtype=jnp.int32)              # [b, s, E]
        pos = counts[:, None, :] + jnp.cumsum(e_onehot, axis=1) - e_onehot
        keep = (pos < C) & (e_onehot > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                                dtype=h.dtype)[..., :C]         # [b, s, E, C]
        sel = pos_oh * keep[..., None].astype(h.dtype)
        dispatch = dispatch + sel
        combine = combine + sel.astype(jnp.float32) * \
            gate_vals[..., choice][..., None, None]
        counts = counts + e_onehot.sum(axis=1)

    dispatch = shard(dispatch, "batch", "seq", "experts", None)
    xe = jnp.einsum("bsec,bsd->becd", dispatch, h)              # [b, E, C, d]
    xe = shard(xe, "batch", "experts", None, "embed")

    up = layers.einsum_f32("becd,edf->becf", xe, params["wi"]).astype(h.dtype)
    up = shard(up, "batch", "experts", None, "mlp_act")
    if layers.is_glu(cfg.activation):
        gate, val = jnp.split(up, 2, axis=-1)
        act = layers.glu_combine(cfg.activation, gate, val)
    else:
        act = layers.ACTIVATIONS[cfg.activation](up)
    ye = layers.einsum_f32("becf,efd->becd", act, params["wo"]).astype(h.dtype)

    y = jnp.einsum("bsec,becd->bsd", combine.astype(h.dtype), ye)
    y = shard(y, "batch", "seq", "embed")

    # Aux losses: Switch load-balance + router z-loss.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(frac_tokens * mean_probs)
    z_loss = jnp.mean(jnp.square(jax.scipy.special.logsumexp(logits, -1)))
    return y, {"lb_loss": lb_loss, "z_loss": z_loss}
