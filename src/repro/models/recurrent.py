"""Recurrent sequence mixers: xLSTM's mLSTM and sLSTM, Griffin's RG-LRU.

TPU adaptation (DESIGN.md §6):
  - mLSTM uses the chunkwise-parallel form (kernels/mlstm_scan or the blocked
    jnp mirror) — MXU-dense within chunks, compact state across chunks.
  - RG-LRU is a *diagonal* linear recurrence -> jax.lax.associative_scan
    (log-depth, parallel) instead of a sequential stream.
  - sLSTM has a genuinely nonlinear recurrence (h feeds the gates) and cannot
    be parallelized over time; it runs as lax.scan. This is why xLSTM uses
    them sparsely (1-in-8) — the config pattern reflects that.

Gate simplification vs. the papers (documented deviation, DESIGN.md §9):
RG-LRU gates are per-channel diagonal (w ⊙ x) rather than block-diagonal
projections; parameter counts in configs/base.py match this implementation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.parallel.axes import shard


# ===========================================================================
# mLSTM block (xLSTM)
# ===========================================================================

def mlstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    inner = int(cfg.mlstm_proj_factor * d)
    bs = cfg.mlstm_qk_blocksize
    nb = inner // bs
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "norm": layers.rmsnorm_init(d, dt),
        "w_up": layers.dense_init(ks[0], d, 2 * inner, dt),
        "conv": layers.conv1d_init(cfg.mlstm_conv_width, inner, dt),
        "wq": (jax.random.normal(ks[1], (nb, bs, bs), jnp.float32)
               * layers.INIT_STD).astype(dt),
        "wk": (jax.random.normal(ks[2], (nb, bs, bs), jnp.float32)
               * layers.INIT_STD).astype(dt),
        "w_i": layers.dense_init(ks[3], inner, cfg.n_heads, jnp.float32),
        "b_i": jnp.zeros((cfg.n_heads,), jnp.float32),
        "w_f": layers.dense_init(ks[4], inner, cfg.n_heads, jnp.float32),
        "b_f": jnp.full((cfg.n_heads,), 3.0, jnp.float32),  # open forget gates
        "gnorm": layers.rmsnorm_init(inner, dt),
        "w_down": layers.dense_init(ks[5], inner, d, dt),
    }


def _blockdiag(x, w):
    """x [..., nb*bs] @ block-diagonal w [nb, bs, bs]."""
    nb, bs, _ = w.shape
    xs = x.reshape(x.shape[:-1] + (nb, bs))
    y = jnp.einsum("...nb,nbc->...nc", xs, w.astype(x.dtype))
    return y.reshape(x.shape)


def mlstm_state_init(cfg: ModelConfig, batch: int, abstract: bool = False):
    d = cfg.d_model
    inner = int(cfg.mlstm_proj_factor * d)
    h = cfg.n_heads
    hd = inner // h
    cw = cfg.mlstm_conv_width
    mk = (jax.ShapeDtypeStruct if abstract
          else (lambda sh, dt: jnp.zeros(sh, dt)))
    return {
        "C": mk((batch, h, hd, hd), jnp.float32),
        "n": mk((batch, h, hd), jnp.float32),
        "m": mk((batch, h, 1), jnp.float32),
        "conv": mk((batch, cw - 1, inner), jnp.bfloat16),
    }


def mlstm_apply(params, cfg: ModelConfig, x, state=None, decode: bool = False,
                backend: Optional[str] = None, chunk: int = 128,
                positions=None):
    """x [b, s, d] -> (y, new_state or None).

    `positions` [b, s] (serving chunked prefill) marks -1 entries as
    trailing padding — padded steps are made state-transparent (forget
    gate pinned open, input gate shut, conv carry ends at the last valid
    input) — and rows whose chunk starts at position 0 restart the scan
    from a fresh state instead of the lane's previous occupant's.
    """
    from repro.kernels import ops as kops
    b, s, d = x.shape
    inner = int(cfg.mlstm_proj_factor * d)
    h_heads = cfg.n_heads
    hd = inner // h_heads
    chunked = state is not None and positions is not None and not decode
    if chunked:
        valid = positions >= 0                          # [b, s]
        fresh = positions[:, 0] == 0                    # [b]

    hin = layers.rmsnorm(params["norm"], x, cfg.norm_eps)
    up = layers.matmul(hin, params["w_up"])
    x_m, z = jnp.split(up, 2, axis=-1)
    x_m = shard(x_m, "batch", "seq", "inner")
    conv_state = state["conv"] if state is not None else None
    if chunked:
        conv_state = jnp.where(fresh[:, None, None],
                               jnp.zeros_like(conv_state), conv_state)
        xc, new_conv = layers.causal_conv1d(params["conv"], x_m, conv_state,
                                            valid_len=valid.sum(axis=1))
    else:
        xc, new_conv = layers.causal_conv1d(params["conv"], x_m, conv_state)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    q = _blockdiag(xc, params["wq"]).reshape(b, s, h_heads, hd)
    k = _blockdiag(xc, params["wk"]).reshape(b, s, h_heads, hd)
    v = x_m.reshape(b, s, h_heads, hd)
    i_gate = (jnp.einsum("bsi,ih->bsh", xc.astype(jnp.float32), params["w_i"])
              + params["b_i"])
    f_gate = (jnp.einsum("bsi,ih->bsh", xc.astype(jnp.float32), params["w_f"])
              + params["b_f"])
    if chunked:
        # padded steps: log_sigmoid(1e4) == 0.0 exactly in f32 (state
        # decays by exp(0) = 1) and the -1e30 input gate contributes
        # exp(-1e30 - m) == 0 — the scan passes state straight through
        v3 = valid[..., None]
        i_gate = jnp.where(v3, i_gate, kops.NEG_INF)
        f_gate = jnp.where(v3, f_gate, 1e4)

    if decode:
        assert state is not None and s == 1
        out, (C, n, m) = kops.mlstm_decode_step(
            q[:, 0], k[:, 0], v[:, 0], i_gate[:, 0], f_gate[:, 0],
            (state["C"], state["n"], state["m"]))
        out = out[:, None]
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    elif state is not None:
        C0, n0, m0 = state["C"], state["n"], state["m"]
        if chunked:
            C0 = jnp.where(fresh[:, None, None, None],
                           jnp.zeros_like(C0), C0)
            n0 = jnp.where(fresh[:, None, None], jnp.zeros_like(n0), n0)
            # a fresh scan's stabilizer starts at -inf, not 0 — anything
            # else shifts the denominator clamp exp(-m_t) on chunk 1
            m0 = jnp.where(fresh[:, None, None], kops.NEG_INF, m0)
        scan_fn = jax.checkpoint(
            lambda q_, k_, v_, i_, f_, C_, n_, m_: kops.mlstm_scan(
                q_, k_, v_, i_, f_, chunk=chunk, backend=backend,
                initial=(C_, n_, m_)))
        out, (C, n, m) = scan_fn(q, k, v, i_gate, f_gate, C0, n0, m0)
        new_state = {"C": C, "n": n, "m": m, "conv": new_conv}
    else:
        # checkpoint: backward recomputes the chunk scan instead of stashing
        # every chunk's (dk×dv) carry for every layer simultaneously
        # (EXPERIMENTS §Perf: 69.5 -> ~3 GiB/dev on xlstm train_4k)
        scan_fn = jax.checkpoint(
            lambda *a: kops.mlstm_scan(*a, chunk=chunk, backend=backend))
        out, (C, n, m) = scan_fn(q, k, v, i_gate, f_gate)
        new_state = None

    out = out.reshape(b, s, inner)
    out = layers.groupnorm_heads(params["gnorm"], out, h_heads, cfg.norm_eps)
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(out.dtype)
    y = layers.matmul(out, params["w_down"])
    return shard(y, "batch", "seq", "embed"), new_state


# ===========================================================================
# sLSTM block (xLSTM) — sequential scan, block-diagonal recurrence per head
# ===========================================================================

def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ff = cfg.slstm_ff_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "norm": layers.rmsnorm_init(d, dt),
        "w": layers.dense_init(ks[0], d, 4 * d, dt),          # z, i, f, o
        "r": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32)
              * layers.INIT_STD).astype(jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.full((d,), 3.0, jnp.float32),
                              jnp.zeros((d,), jnp.float32)]),
        "norm2": layers.rmsnorm_init(d, dt),
        "w_ff": layers.dense_init(ks[2], d, 2 * ff, dt),
        "w_ff_out": layers.dense_init(ks[3], ff, d, dt),
    }


def slstm_state_init(cfg: ModelConfig, batch: int, abstract: bool = False):
    d = cfg.d_model
    mk = (jax.ShapeDtypeStruct if abstract
          else (lambda sh, dt: jnp.zeros(sh, dt)))
    return {
        "c": mk((batch, d), jnp.float32),
        "n": mk((batch, d), jnp.float32),
        "h": mk((batch, d), jnp.float32),
        "m": mk((batch, d), jnp.float32),
    }


def _slstm_step(params, cfg, xw_t, state):
    """xw_t [b, 4d] (input projection); state dict of [b, d] f32."""
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    c, n, hid, m = state["c"], state["n"], state["h"], state["m"]
    b = hid.shape[0]
    rec = jnp.einsum("bhx,hxy->bhy", hid.reshape(b, h, hd),
                     params["r"]).reshape(b, 4 * d)
    pre = xw_t.astype(jnp.float32) + rec + params["b"]
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(zt)
    o = jax.nn.sigmoid(ot)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    fp = jnp.exp(logf + m - m_new)
    ip = jnp.exp(it - m_new)
    c = fp * c + ip * z
    n = fp * n + ip
    h_new = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h_new, "m": m_new}


def slstm_apply(params, cfg: ModelConfig, x, state=None, decode: bool = False,
                positions=None):
    b, s, d = x.shape
    hin = layers.rmsnorm(params["norm"], x, cfg.norm_eps)
    xw = layers.matmul(hin, params["w"])                      # [b, s, 4d]
    st = state if state is not None else slstm_state_init(cfg, b)
    core = {k: st[k] for k in ("c", "n", "h", "m")}
    chunked = state is not None and positions is not None and not decode
    if chunked:
        # serving chunked prefill: first chunks (position 0) restart from
        # zero state; -1 positions are trailing padding and must leave the
        # carried state untouched (per-step select below)
        fresh = (positions[:, 0] == 0)[:, None]
        core = {k: jnp.where(fresh, jnp.zeros_like(v_), v_)
                for k, v_ in core.items()}
        valid = positions >= 0
    else:
        valid = jnp.ones((b, s), jnp.bool_)
    if decode:
        assert s == 1
        core = _slstm_step(params, cfg, xw[:, 0], core)
        hs = core["h"][:, None]
        new_state = core
    else:
        @jax.checkpoint  # recompute the time scan in backward (one layer
        def _scan(core, xw_, valid_):  # of per-step carries live at a time)
            def step(carry, xs):
                xw_t, v_t = xs
                nxt = _slstm_step(params, cfg, xw_t, carry)
                nxt = {k: jnp.where(v_t[:, None], nxt[k], carry[k])
                       for k in nxt}
                return nxt, nxt["h"]
            return jax.lax.scan(step, core, (xw_, valid_))
        core, hs = _scan(core, jnp.moveaxis(xw, 1, 0),
                         jnp.moveaxis(valid, 1, 0))
        hs = jnp.moveaxis(hs, 0, 1)
        new_state = core if state is not None else None
    y = x + hs.astype(x.dtype)                                 # residual core
    # post-up GLU feed-forward (xLSTM sLSTM block, ff factor 4/3)
    hff = layers.rmsnorm(params["norm2"], y, cfg.norm_eps)
    up = layers.matmul(hff, params["w_ff"])
    gate, val = jnp.split(up, 2, axis=-1)
    ff = layers.glu_combine("swiglu", gate, val)
    out = layers.matmul(ff, params["w_ff_out"])
    return shard(out + hs.astype(x.dtype), "batch", "seq", "embed"), new_state


# ===========================================================================
# RG-LRU block (Griffin / RecurrentGemma)
# ===========================================================================

def rglru_init(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    # a_param init so that a = exp(-8*softplus(a_param)*r) spans ~[0.9, 0.999]
    u = jax.random.uniform(ks[3], (w,), jnp.float32, 0.25, 0.75)
    a_param = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))
    return {
        "norm": layers.rmsnorm_init(d, dt),
        "w_x": layers.dense_init(ks[0], d, w, dt),
        "w_y": layers.dense_init(ks[1], d, w, dt),
        "conv": layers.conv1d_init(cfg.conv_width, w, dt),
        "gate_r": jnp.zeros((w,), jnp.float32),   # diag recurrence gate
        "gate_i": jnp.zeros((w,), jnp.float32),   # diag input gate
        "a_param": a_param,
        "w_out": layers.dense_init(ks[2], w, d, dt),
    }


def rglru_state_init(cfg: ModelConfig, batch: int, abstract: bool = False):
    w = cfg.lru_width or cfg.d_model
    cw = cfg.conv_width
    mk = (jax.ShapeDtypeStruct if abstract
          else (lambda sh, dt: jnp.zeros(sh, dt)))
    return {
        "h": mk((batch, w), jnp.float32),
        "conv": mk((batch, cw - 1, w), jnp.bfloat16),
    }


def rglru_apply(params, cfg: ModelConfig, x, state=None, decode: bool = False,
                positions=None):
    """Griffin recurrent block: gelu branch ⊙ RG-LRU branch -> out proj.

    `positions` [b, s] (serving chunked prefill): -1 padding steps become
    identity elements of the scan (a = 1, B = 0) and rows starting at
    position 0 restart from h = 0 / empty conv history.
    """
    b, s, d = x.shape
    wdt = params["w_x"].shape[1]
    chunked = state is not None and positions is not None and not decode
    if chunked:
        valid = positions >= 0                               # [b, s]
        fresh = positions[:, 0] == 0                         # [b]
    hin = layers.rmsnorm(params["norm"], x, cfg.norm_eps)
    branch_y = jax.nn.gelu(layers.matmul(hin, params["w_y"])
                           .astype(jnp.float32)).astype(x.dtype)
    bx = layers.matmul(hin, params["w_x"])
    bx = shard(bx, "batch", "seq", "lru")
    conv_state = state["conv"] if state is not None else None
    if chunked:
        conv_state = jnp.where(fresh[:, None, None],
                               jnp.zeros_like(conv_state), conv_state)
        xc, new_conv = layers.causal_conv1d(params["conv"], bx, conv_state,
                                            valid_len=valid.sum(axis=1))
    else:
        xc, new_conv = layers.causal_conv1d(params["conv"], bx, conv_state)

    xf = xc.astype(jnp.float32)
    r_pre = params["gate_r"] * xf
    i_pre = params["gate_i"] * xf
    log_a = (-8.0 * jax.nn.softplus(params["a_param"])
             * jax.nn.sigmoid(r_pre))                        # [b, s, w] < 0
    if chunked:
        log_a = jnp.where(valid[..., None], log_a, 0.0)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * jax.nn.sigmoid(i_pre) * xf                # B term
    if chunked:
        gated = jnp.where(valid[..., None], gated, 0.0)

    h0 = state["h"] if state is not None else jnp.zeros((b, wdt), jnp.float32)
    if chunked:
        h0 = jnp.where(fresh[:, None], jnp.zeros_like(h0), h0)
    if decode:
        assert s == 1
        h = a[:, 0] * h0 + gated[:, 0]
        hs = h[:, None]
        new_state = {"h": h, "conv": new_conv}
    else:
        # Diagonal linear recurrence h_t = a_t h_{t-1} + B_t with initial h0:
        # fold h0 into the first step then associative_scan (parallel).
        g0 = gated.at[:, 0].add(a[:, 0] * h0)
        def combine(u, w_):
            a1, b1 = u
            a2, b2 = w_
            return a1 * a2, a2 * b1 + b2
        _, hs = jax.lax.associative_scan(combine, (a, g0), axis=1)
        new_state = ({"h": hs[:, -1], "conv": new_conv}
                     if state is not None else None)

    out = (hs.astype(x.dtype) * branch_y)
    y = layers.matmul(out, params["w_out"])
    return shard(y, "batch", "seq", "embed"), new_state
