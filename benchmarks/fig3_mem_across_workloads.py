"""Paper Fig. 3 — memory requirement across workloads at the same input
size: all 10 archs (reduced), matched token budget, measured per-device
peak + classification. Paper Fig. 6 (shuffle/transient bytes across
workloads) falls out of the same sweep and is emitted alongside.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, flush, measurer


def main():
    from repro.configs import ARCH_IDS, get_config
    from repro.configs.base import ShapeConfig, TRAIN
    from repro.core import profiler as PF
    from repro.core.predictor import MemoryPlan
    from repro.core.classifier import classify_profiles

    m = measurer()
    plan = MemoryPlan()
    shape = ShapeConfig("t", TRAIN, 256, 8)   # same input size for all
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        t0 = time.perf_counter()
        ladder = PF.profile_ladder(cfg, shape, None, plan, n_points=3,
                                   base_seq=64, measurer=m)
        us = (time.perf_counter() - t0) * 1e6
        p = ladder[-1]
        cls = classify_profiles(ladder)
        emit(f"fig3.peak.{arch}", us,
             f"peak_bytes={p.peak_bytes:.0f};category={cls.category.value};"
             f"alpha={cls.alpha:.2f};inc={cls.inc:.2f}")
        emit(f"fig6.transient.{arch}", 0.0,
             f"temp_bytes={p.transient_bytes:.0f};"
             f"input_bytes={p.input_bytes:.0f};"
             f"stage_temp={p.stage_transient_bytes:.0f}")
    flush()


if __name__ == "__main__":
    main()
