"""Paper Table IV — WSMC-guided memory capacity configurations: per
workload × 3 input sizes, the planned knobs + predicted capacity
(the paper's Memory Configuration column).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, flush, measurer


def main():
    from repro.configs import ARCH_IDS, get_config
    from repro.configs.base import ShapeConfig, TRAIN
    from repro.core import profiler as PF
    from repro.core.classifier import classify_profiles
    from repro.search import space as SPC
    from repro.search import strategies as ST

    m = measurer()
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        base = ShapeConfig("t", TRAIN, 256, 8)
        t0 = time.perf_counter()
        cls = classify_profiles(
            PF.profile_ladder(cfg, base, None, n_points=3, base_seq=64,
                              measurer=m))
        profile_us = (time.perf_counter() - t0) * 1e6
        for seq in (128, 256, 512):
            shape = ShapeConfig(f"t{seq}", TRAIN, seq, 8)
            space = SPC.paper_space(cfg, shape, m.mesh_shape)
            t0 = time.perf_counter()
            dec = ST.fastest_first(space, cfg, shape, cls)
            us = (time.perf_counter() - t0) * 1e6
            emit(f"table4.{arch}.seq{seq}", us,
                 f"category={cls.category.value};remat={dec.plan.remat};"
                 f"micro={dec.plan.microbatches};opt={dec.plan.optimizer};"
                 f"capacity_mb={dec.prediction.capacity_bytes/2**20:.1f}")
        emit(f"table4.{arch}.profile_cost", profile_us, "online_phase_ladder")
    flush()


if __name__ == "__main__":
    main()
