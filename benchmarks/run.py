"""Benchmark harness — one module per paper table/figure (DESIGN.md §8).

Prints ``name,us_per_call,derived`` CSV. Mesh-dependent benchmarks run in
subprocesses with 8 fake CPU devices so this process keeps the default
single device (dry-run rule).
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

MESH_BENCHES = [
    "benchmarks.fig2_mem_vs_input",
    "benchmarks.fig3_mem_across_workloads",
    "benchmarks.table4_planned_configs",
    "benchmarks.fig7_fig8_policies",
    "benchmarks.serve_throughput",
]
LOCAL_BENCHES = [
    "benchmarks.kernels_micro",
]


def _run_subprocess(module: str, backend: str = "compile",
                    profile_cache: str = "") -> int:
    env = dict(os.environ)
    env["WSMC_BACKEND"] = backend
    if profile_cache:
        env["WSMC_PROFILE_CACHE"] = profile_cache
    if backend == "compile":
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run([sys.executable, "-m", module], env=env,
                          capture_output=True, text=True, timeout=3000)
    sys.stdout.write(proc.stdout)
    if proc.returncode != 0:
        sys.stderr.write(proc.stderr[-4000:])
        print(f"{module},0.0,FAILED")
    return proc.returncode


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=os.environ.get("WSMC_BACKEND",
                                                        "compile"),
                    choices=["compile", "simulate"],
                    help="memory-measurement backend for the WSMC sweeps "
                         "(simulate = zero XLA compiles, seconds not minutes)")
    ap.add_argument("--profile-cache",
                    default=os.environ.get("WSMC_PROFILE_CACHE", ""),
                    help="on-disk MemoryProfile cache path shared by all "
                         "benchmark modules")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    failures = 0
    for module in MESH_BENCHES:
        failures += _run_subprocess(module, args.backend,
                                    args.profile_cache) != 0
    for module in LOCAL_BENCHES:
        import importlib
        importlib.import_module(module).main()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
