"""Paper Fig. 2 — memory capacity requirement vs input size (4 workloads ×
input ladder): measured static peak vs WSMC prediction (paper-factor and
fitted modes). Also validates the predictor's remat scalers.

Run inside an 8-device process (benchmarks.run handles that).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, flush, measurer

ARCHS = ["h2o-danube-1.8b", "mixtral-8x7b", "xlstm-1.3b", "gemma3-12b"]
SEQS = [64, 128, 256, 512]


def main():
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TRAIN
    from repro.core.classifier import classify_profiles
    from repro.core.predictor import MemoryPlan, predict

    m = measurer()
    plan = MemoryPlan()
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        profiles = []
        for seq in SEQS:
            shape = ShapeConfig(f"t{seq}", TRAIN, seq, 8)
            t0 = time.perf_counter()
            p = m.measure(cfg, shape, plan)
            us = (time.perf_counter() - t0) * 1e6
            profiles.append(p)
            emit(f"fig2.measure.{arch}.seq{seq}", us,
                 f"peak_bytes={p.peak_bytes:.0f};temp={p.transient_bytes:.0f}"
                 f";alpha={p.alpha:.2f}")
        # fit on the first 3 points, predict the 4th (paper's online phase)
        cls = classify_profiles(profiles[:3])
        target = ShapeConfig("t", TRAIN, SEQS[-1], 8)
        for mode in ("paper", "fitted"):
            pred = predict(cfg, target, plan, cls, m.mesh_shape,
                           mode=mode)
            actual = profiles[-1].peak_bytes
            err = (pred.resident_bytes + pred.transient_bytes) / max(
                profiles[-1].argument_bytes + profiles[-1].transient_bytes, 1)
            emit(f"fig2.predict.{arch}.{mode}", 0.0,
                 f"category={cls.category.value};pred_capacity="
                 f"{pred.capacity_bytes:.0f};measured_peak={actual:.0f};"
                 f"pred_over_measured={err:.2f}")
    flush()


if __name__ == "__main__":
    main()
