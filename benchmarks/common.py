"""Shared benchmark machinery.

Every benchmark mirrors one paper table/figure (DESIGN.md §8) and prints
``name,us_per_call,derived`` CSV rows. Benchmarks run on a small in-process
mesh (8 fake devices via subprocess guard) or single device — they measure
the WSMC machinery itself (planning cost, prediction accuracy), not TPU
wall-clock, which the roofline covers.
"""
from __future__ import annotations

import contextlib
import os
import sys
import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timed(name: str, fn: Callable, *args, repeat: int = 1, derived: str = "",
          **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    ROWS.append((name, us, derived))
    return out


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))


def flush():
    for name, us, derived in ROWS:
        print(f"{name},{us:.1f},{derived}")
    ROWS.clear()


def small_mesh(shape=(4, 2), axes=("data", "model")):
    from repro.launch.mesh import make_mesh
    return make_mesh(shape, axes)


def ensure_devices(n: int = 8):
    """Benchmarks that need a mesh re-exec themselves with fake devices."""
    import jax
    if len(jax.devices()) >= n:
        return True
    return False
