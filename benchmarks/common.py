"""Shared benchmark machinery.

Every benchmark mirrors one paper table/figure (DESIGN.md §8) and prints
``name,us_per_call,derived`` CSV rows. Benchmarks run on a small in-process
mesh (8 fake devices via subprocess guard) or single device — they measure
the WSMC machinery itself (planning cost, prediction accuracy), not TPU
wall-clock, which the roofline covers.
"""
from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timed(name: str, fn: Callable, *args, repeat: int = 1, derived: str = "",
          **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    ROWS.append((name, us, derived))
    return out


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))


def flush():
    for name, us, derived in ROWS:
        print(f"{name},{us:.1f},{derived}")
    ROWS.clear()


def small_mesh(shape=(4, 2), axes=("data", "model")):
    from repro.launch.mesh import make_mesh
    return make_mesh(shape, axes)


# The benchmark mesh as a plain {axis: size} dict — all the simulator needs.
SMALL_MESH_SHAPE = {"data": 4, "model": 2}


def backend() -> str:
    """Measurement backend for this benchmark run: WSMC_BACKEND env var,
    'compile' (XLA ground truth) by default, 'simulate' for the zero-compile
    analytical sweeps."""
    return os.environ.get("WSMC_BACKEND", "compile")


def measurer(mesh=None):
    """Build the run's MemoryMeasurer. Under 'simulate' no jax mesh (hence
    no fake-device subprocess) is required; under 'compile' a real mesh is
    built unless one is passed in. WSMC_PROFILE_CACHE points the on-disk
    profile cache."""
    from repro.core import measure as MM
    cache_path = os.environ.get("WSMC_PROFILE_CACHE")
    cache = MM.ProfileCache(cache_path) if cache_path else None
    if backend() == "simulate":
        return MM.SimulatedMeasurer(
            SMALL_MESH_SHAPE if mesh is None else mesh, cache=cache)
    return MM.CompileMeasurer(mesh if mesh is not None else small_mesh(),
                              cache=cache)


def ensure_devices(n: int = 8):
    """Benchmarks that need a mesh re-exec themselves with fake devices."""
    import jax
    if len(jax.devices()) >= n:
        return True
    return False
