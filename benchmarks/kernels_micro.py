"""Kernel microbenchmarks: blocked-jnp backends vs naive reference on CPU
(wall time + allclose), plus interpret-mode validation cost. On TPU these
rows become the pallas-vs-XLA comparison."""
from __future__ import annotations

import time

from benchmarks.common import emit, flush


def main():
    import jax
    import jax.numpy as jnp
    from repro.configs.base import BlockSpec
    from repro.kernels import ops
    from repro.models import attention as A

    key = jax.random.PRNGKey(0)
    b, s, K, G, hd = 2, 1024, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, K, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, K, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    for name, blk in [("causal", BlockSpec()),
                      ("window256", BlockSpec(window=256)),
                      ("chunk256", BlockSpec(chunk=256))]:
        st = A.AttnSettings(backend="blocked", q_block=256, kv_block=256)
        f = jax.jit(lambda q, k, v, blk=blk, st=st:
                    A._seq_attention(q, k, v, pos, pos, blk, st))
        out = f(q, k, v)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            out = f(q, k, v)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / 3 * 1e6
        fn = jax.jit(lambda q, k, v, blk=blk:
                     A._naive(q, k, v, pos, pos, blk))
        ref = fn(q, k, v)
        err = float(jnp.abs(out - ref).max())
        emit(f"kernels.attn_blocked.{name}", us, f"max_err={err:.1e};s={s}")

    # mLSTM chunked vs sequential ref
    h, dk, dv = 2, 32, 32
    ks = jax.random.split(key, 5)
    q2 = jax.random.normal(ks[0], (b, s, h, dk)) * 0.5
    k2 = jax.random.normal(ks[1], (b, s, h, dk)) * 0.5
    v2 = jax.random.normal(ks[2], (b, s, h, dv))
    ig = jax.random.normal(ks[3], (b, s, h))
    fg = jax.random.normal(ks[4], (b, s, h)) + 2.0
    fb = jax.jit(lambda *a: ops.mlstm_scan(*a, chunk=128,
                                           backend="blocked")[0])
    out = fb(q2, k2, v2, ig, fg)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fb(q2, k2, v2, ig, fg)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) * 1e6
    fr = jax.jit(lambda *a: ops.mlstm_scan(*a, backend="ref")[0])
    refo = fr(q2, k2, v2, ig, fg)
    jax.block_until_ready(refo)
    t0 = time.perf_counter()
    refo = fr(q2, k2, v2, ig, fg)
    jax.block_until_ready(refo)
    us_ref = (time.perf_counter() - t0) * 1e6
    err = float(jnp.abs(out - refo).max())
    emit("kernels.mlstm_chunked", us,
         f"max_err={err:.1e};sequential_ref_us={us_ref:.0f};"
         f"speedup={us_ref/max(us,1):.1f}x")
    flush()


if __name__ == "__main__":
    main()
