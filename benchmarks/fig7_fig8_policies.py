"""Paper Figs. 7 & 8 — the policy comparison, run through the unified
`repro.search` subsystem:

  default — static conservative config (Spark's 2 GB analogue): full remat,
            deep microbatching, adafactor, full-HBM capacity request
  wsmc    — strategies.fastest_first over the paper space (§III-E walk)
  staged  — simulator-screened top-k, verified on the run's backend
            (oracle quality in O(k) expensive measurements)
  proper  — strategies.exhaustive_verified: the paper's manually-found
            configuration (measure-verify the whole walk)

Fig. 7 analogue: measured wall-clock of one train step per policy (CPU,
reduced config — the *relative* ordering is the claim) plus the analytic
step-time penalty. Fig. 8 analogue: measured per-device peak bytes and the
capacity each policy would request.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import backend, emit, flush, measurer

ARCHS = ["h2o-danube-1.8b", "mixtral-8x7b", "xlstm-1.3b"]


def main():
    from repro import hw as HW
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig, TRAIN
    from repro.core import measure as MM
    from repro.core import planner as PL
    from repro.core import profiler as PF
    from repro.core.classifier import classify_profiles
    from repro.search import space as SPC
    from repro.search import strategies as ST

    m = measurer()
    shape = ShapeConfig("t", TRAIN, 256, 8)
    # miniature HBM budget so the knob choice is non-trivial at test scale:
    hbm = dataclasses.replace(HW.TPU_V5E, hbm_bytes=64 * 2**20,
                              reserved_bytes=2 * 2**20)

    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        cls = classify_profiles(
            PF.profile_ladder(cfg, shape, None, n_points=3, base_seq=64,
                              measurer=m))
        space = SPC.paper_space(cfg, shape, m.mesh_shape)

        policies = {}
        policies["default"] = PL.default_plan(cfg, shape)
        t0 = time.perf_counter()
        policies["wsmc"] = ST.fastest_first(space, cfg, shape, cls,
                                            hw=hbm).plan
        wsmc_us = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        st = ST.staged(space, cfg, shape,
                       screener=MM.SimulatedMeasurer(m.mesh_shape),
                       verifier=m, k=5, hw=hbm)
        staged_us = (time.perf_counter() - t0) * 1e6
        policies["staged"] = st.plan
        t0 = time.perf_counter()
        ex = ST.exhaustive_verified(space, cfg, shape, hw=hbm,
                                    max_candidates=6, measurer=m)
        oracle_us = (time.perf_counter() - t0) * 1e6
        policies["proper"] = ex.plan
        emit(f"policies.search_cost.{arch}", wsmc_us,
             f"wsmc_prediction_only;oracle_us={oracle_us:.0f};"
             f"oracle_measures={ex.measured};staged_us={staged_us:.0f};"
             f"staged_measures={st.measured};backend={m.backend}")

        for name, plan in policies.items():
            # Fig. 8: memory
            peak = m.measure_peak(cfg, shape, plan)
            capacity = (hbm.hbm_bytes if name == "default"
                        else HW.capacity_from_requirement(peak, 0.0, hbm))
            emit(f"fig8.mem.{arch}.{name}", 0.0,
                 f"peak_bytes={peak:.0f};capacity_bytes={capacity:.0f}")
            # Fig. 7: step time (3 steps, after 1 warmup). Real execution —
            # only meaningful (and only possible) with live devices, so the
            # simulate backend reports the analytic penalty alone.
            if backend() == "simulate":
                emit(f"fig7.time.{arch}.{name}", 0.0,
                     f"remat={plan.remat};micro={plan.microbatches};"
                     f"opt={plan.optimizer};"
                     f"penalty={plan.step_time_penalty():.2f};analytic_only")
                continue
            step_us = _timed_step(cfg, shape, plan)
            emit(f"fig7.time.{arch}.{name}", step_us,
                 f"remat={plan.remat};micro={plan.microbatches};"
                 f"opt={plan.optimizer};penalty={plan.step_time_penalty():.2f}")
    flush()


def _timed_step(cfg, shape, plan):
    import jax
    import jax.numpy as jnp
    from repro.core import profiler as PF
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.models import init_params
    from repro.optim import optimizers as opt
    from repro.runtime.train_step import make_train_step

    params = init_params(jax.random.PRNGKey(0), cfg)
    tcfg = PF._tcfg_for(plan)
    step = jax.jit(make_train_step(cfg, tcfg))
    ostate = opt.init_state(tcfg.optimizer, params)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=shape.seq_len,
                                    global_batch=shape.global_batch))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    params, ostate, _ = step(params, ostate, batch, jnp.asarray(0))
    t0 = time.perf_counter()
    for s in range(3):
        params, ostate, metrics = step(params, ostate, batch,
                                       jnp.asarray(s + 1))
    jax.block_until_ready(metrics["loss"])
    return (time.perf_counter() - t0) / 3 * 1e6


if __name__ == "__main__":
    main()
