"""Serving frontier: ring-slot vs paged vs paged+compaction engines under
the SAME per-budget HBM envelope, swept over several budgets (the PR-6
acceptance benchmark).

Each budget is sized between the k- and (k+1)-worst-case-ring-slot
requirements (Eq. 11 headroom included), so ring admits exactly k
sequences; the paged planners re-answer the same capacity question over a
block pool with the trace's own length distribution, and the compacted
planner additionally charges the decode transient at the EXPECTED lane
width (bucketed), not the worst case. Per cell: admitted concurrency (the
paper's capacity metric per HBM byte), generated tokens/s wall (warm —
compiles paid by a throwaway run), tokens/tick, mean request latency in
ticks, decode-lane occupancy, mean decode width, and compile counts.
Token streams are asserted identical across all three modes (scheduling,
memory layout, lane packing, and chunked prefill must never change
outputs). The acceptance pin sits at the TIGHTEST budget — the regime the
paper targets — where paged+compaction must reach >= ring tokens/s while
admitting >= 4x ring's concurrency; looser budgets stay in the frontier
as data (once the budget covers the whole long tail with worst-case
rings, ring serves it without table indirection and catches back up —
the README's "when ring still wins"). Results land in BENCH_serving.json
at the repo root.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit, flush

ARCH = "mistral-nemo-12b"            # pure global attention: every layer pages
RING_SLOT_BUDGETS = (2, 3, 4)        # budget sized to admit exactly k rings
LANE_CAP = 8                         # engine slot cap (ShapeConfig batch)


def main():
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.configs.base import DECODE, ShapeConfig
    from repro.core import measure as MM
    from repro.core import predictor as PR
    from repro.core import profiler as PF
    from repro.models import init_params
    from repro.search import execplan as XP
    from repro.search import space as SP
    from repro.serving import (BlockAllocator, Engine, synthetic_trace,
                               trace_context)
    from repro.serving.executor import JaxExecutor, PagedJaxExecutor

    cfg = get_config(ARCH).reduced()
    # mostly-short traffic with a long-generation tail: the mix where
    # worst-case ring slots waste the most (every short request still pays
    # max-context bytes) and where lane compaction matters (the tail drains
    # at low occupancy)
    trace = synthetic_trace(12, vocab_size=cfg.vocab_size, seed=0,
                            prompt_lens=(4, 8), gen_lens=(4, 4, 8, 248),
                            mean_interarrival=0.5)
    context = trace_context(trace)
    shape = ShapeConfig("bench_serve", DECODE, context, LANE_CAP)
    mesh_shape = {"data": 1, "model": 1}
    sim = MM.SimulatedMeasurer(mesh_shape)
    cls = PF.classify_workload(cfg, shape, None, n_points=2, base_seq=64,
                               measurer=sim)
    seq_lens = [len(r.prompt) + r.max_new - 1 for r in trace]

    def req(n):
        sh = dataclasses.replace(shape, global_batch=n)
        return PR.predict(cfg, sh, PR.MemoryPlan(), cls,
                          mesh_shape).capacity_bytes

    def pinned(kv_blocks):
        return SP.serving_space(cfg, shape, max_devices=1, data=(1,),
                                model=(1,), kv_blocks=kv_blocks)

    def build(splan, mode):
        n_slots = splan.slots(cap=min(LANE_CAP, len(trace)))
        if mode == "ring":
            return (JaxExecutor(params, cfg, n_slots=n_slots,
                                context=context), None, n_slots, 0)
        n_blocks = splan.pool_blocks(n_slots, context)
        compact = mode == "paged_compact"
        chunk = 2 * splan.kv_block if compact else 0
        ex = PagedJaxExecutor(params, cfg, n_lanes=n_slots,
                              n_blocks=n_blocks, kv_block=splan.kv_block,
                              context=context, compact=compact, chunk=chunk)
        return ex, BlockAllocator(n_blocks, splan.kv_block), n_slots, chunk

    params = init_params(jax.random.PRNGKey(0), cfg)
    frontier = []
    for k in RING_SLOT_BUDGETS:
        budget = (req(k) + req(k + 1)) / 2
        _, ring = XP.plan_serving(cfg, shape, n_devices=1, hbm_budget=budget,
                                  cls=cls, space=pinned((0,)))
        _, paged = XP.plan_serving(cfg, shape, n_devices=1, hbm_budget=budget,
                                   cls=cls, space=pinned((4, 8, 16)),
                                   kv="paged", seq_lens=seq_lens)
        _, pcomp = XP.plan_serving(cfg, shape, n_devices=1, hbm_budget=budget,
                                   cls=cls, space=pinned((4, 8, 16)),
                                   kv="paged", seq_lens=seq_lens,
                                   compact=True)
        cells = {}
        tokens = {}
        for mode, splan in (("ring", ring), ("paged", paged),
                            ("paged_compact", pcomp)):
            # warm run pays every compile; the timed run measures serving
            executor, allocator, n_slots, chunk = build(splan, mode)
            Engine(executor, n_slots, allocator=allocator,
                   chunk_prefill=chunk).run(trace)
            compiles = executor.compile_counts()
            executor, allocator, n_slots, chunk = build(splan, mode)
            engine = Engine(executor, n_slots, allocator=allocator,
                            chunk_prefill=chunk)
            t0 = time.perf_counter()
            report = engine.run(trace)
            wall = time.perf_counter() - t0
            tokens[mode] = [list(c.tokens) for c in report.completions]
            widths = (report.decode_lane_tokens / report.decode_ticks
                      if report.decode_ticks else 0.0)
            cells[mode] = {
                "capacity": splan.capacity,
                "n_slots": n_slots,
                "kv_block": splan.kv_block,
                "blocks": (allocator.n_blocks if allocator else 0),
                "peak_blocks": report.peak_blocks,
                "max_concurrent": report.max_concurrent,
                "concurrency_per_gib": splan.capacity / (budget / 2**30),
                "tokens": report.generated_tokens,
                "ticks": report.ticks,
                "tokens_per_tick": report.throughput(),
                "tokens_per_s": report.generated_tokens / wall,
                "mean_latency_ticks": report.mean_latency(),
                "occupancy": report.occupancy(),
                "mean_decode_width": widths,
                "chunk_calls": report.chunk_calls,
                "prefill_calls": report.prefill_calls,
                "compiles": compiles,
            }
            emit(f"serve.{mode}.b{k}.{ARCH}", wall * 1e6,
                 f"concurrent={report.max_concurrent};"
                 f"tokens_per_s={cells[mode]['tokens_per_s']:.0f};"
                 f"mean_latency={report.mean_latency():.1f};"
                 f"occupancy={report.occupancy():.3f};"
                 f"mean_width={widths:.1f}")
        same = (tokens["ring"] == tokens["paged"] == tokens["paged_compact"])
        ratio = (cells["paged_compact"]["max_concurrent"]
                 / max(cells["ring"]["max_concurrent"], 1))
        speed = (cells["paged_compact"]["tokens_per_s"]
                 / cells["ring"]["tokens_per_s"])
        frontier.append({
            "ring_slots": k,
            "budget_bytes": budget,
            "token_identical": bool(same),
            "concurrency_ratio": ratio,
            "tokens_per_s_ratio": speed,
            **cells,
        })
        emit(f"serve.frontier.b{k}.{ARCH}", 0.0,
             f"compact_vs_ring_concurrency={ratio:.1f}x;"
             f"compact_vs_ring_tokens_per_s={speed:.2f}x;"
             f"token_identical={same}")
        if not same:
            raise SystemExit(f"budget@{k}: token streams diverged")
    tight = frontier[0]
    if tight["tokens_per_s_ratio"] < 1.0:
        raise SystemExit("tightest budget: paged+compaction reached only "
                         f"{tight['tokens_per_s_ratio']:.2f}x ring tokens/s")
    if tight["concurrency_ratio"] < 4.0:
        raise SystemExit("tightest budget: paged+compaction admitted only "
                         f"{tight['concurrency_ratio']:.1f}x ring "
                         "concurrency")
    out = {
        "arch": ARCH,
        "requests": len(trace),
        "context": context,
        "lane_cap": LANE_CAP,
        "frontier": frontier,
    }
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                        "BENCH_serving.json")
    with open(os.path.normpath(path), "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    flush()


if __name__ == "__main__":
    main()
