"""Serving frontier: ring-slot vs paged vs paged+compaction vs
paged+chunked engines under the SAME per-budget HBM envelope, swept over
several budgets (the PR-6 acceptance benchmark), plus the PR-7 OVERLOAD
section: worst-case vs optimistic admission vs optimistic+prefix-sharing
on a prefix-heavy burst trace.

Each budget is sized between the k- and (k+1)-worst-case-ring-slot
requirements (Eq. 11 headroom included), so ring admits exactly k
sequences; the paged planners re-answer the same capacity question over a
block pool with the trace's own length distribution, and the compacted
planner additionally charges the decode transient at the EXPECTED lane
width (bucketed), not the worst case. Per cell: admitted concurrency (the
paper's capacity metric per HBM byte), generated tokens/s wall (warm —
compiles paid by a throwaway run), tokens/tick, mean/percentile request
latency in ticks, TTFT, decode-lane occupancy, mean decode width, compile
counts, and the predicted-vs-actual peak_blocks delta (groundwork for the
calibration loop). Token streams are asserted identical across all
frontier modes (scheduling, memory layout, lane packing, and chunked
prefill must never change outputs), and in every worst-reservation cell
actual block usage is asserted <= the ledger's committed worst case.

The frontier acceptance pin sits at the TIGHTEST budget — the regime the
paper targets — where paged+compaction must reach >= ring tokens/s while
admitting >= 4x ring's concurrency. The OVERLOAD acceptance pin: on a
burst trace whose arrivals exceed worst-case capacity and whose requests
share a 16-token system prompt, optimistic admission + prefix sharing
must admit >= 1.5x the worst-case-reservation concurrency per GiB with
token-identical output.

The PR-8 BENDING section prices the lossy knobs: at a serving-class head
width (the reduced smoke config's head_dim=16 would let the per-position
scale stripes eat the quantization win) and the tightest budget, the same
burst is replayed over fp, int8, int4, and int8+retention block pools.
Every cell now carries bytes-per-admitted-token (paged pool bytes at peak
over generated tokens; 0.0 for ring, whose KV bytes are not block-priced)
and a MEASURED token-agreement rate against exact `greedy_generate`
(shared reference cache, one reference decode per unique prompt).
Bending pins: int8 admits >= 1.8x the fp paged concurrency with measured
agreement >= 0.99; exact cells stay at agreement 1.0.

The PR-9 PREFILL section makes the prefill transient a priced axis: a
prefill-heavy burst (long prompts, short generations) is planned twice
per HBM budget — once charging the tiled flash-prefill kernel's
O(tokens x d) working set, once charging the dense jnp fallback's
O(tokens x context) score matrix — and each plan is replayed through a
token-budgeted chunked engine (Engine(prefill_budget=...)). Every cell
carries prefill_tokens, prefill tokens/tick, and TTFT columns (mean +
percentiles; schema v4 asserts the TTFT columns on every cell in the
file). Prefill pins: at the TIGHTEST budget the tiled-kernel plan must
admit >= 1.3x the dense-plan lanes with LOWER mean TTFT,
token-identically; at the loose budget the two plans converge — the
prefill term only binds where headroom is scarce, which is exactly the
regime the paper targets.

The PR-10 DEGRADATION section prices fault tolerance: the same planned
engine is replayed fault-free and then with a 25% mid-run HBM budget
shrink (live block retirement via `FaultPlan`), with the graceful-
degradation ladder armed and the strict every-tick ledger audit on.
Degradation pins: the shrunk run must sustain >= 0.8x the fault-free
goodput (completed tokens/tick), leak-check clean on the SHRUNKEN pool,
and every completion token-identical to the fault-free replay. Results
land in BENCH_serving.json at the repo root (schema_version 5).
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit, flush

ARCH = "mistral-nemo-12b"            # pure global attention: every layer pages
RING_SLOT_BUDGETS = (2, 3, 4)        # budget sized to admit exactly k rings
LANE_CAP = 8                         # engine slot cap (ShapeConfig batch)
TRACE_SEED = 0                       # stamped into the JSON: same seed +
                                     # knobs => the same replayed workload
OVERLOAD_LANE_CAP = 12               # overload section: admission is the
                                     # contended resource, so more lanes
BEND_LANE_CAP = 24                   # bending section: pool bytes are the
                                     # contended resource, lanes must not cap
PREFILL_LANE_CAP = 16                # prefill section: transient headroom is
                                     # the contended resource
PREFILL_BUDGET_TOKENS = 32           # prompt tokens/tick the budgeted engine
                                     # grants (and the planner charges)
PREFILL_CHUNK = 8                    # chunk_prefill: budget covers 4 chunks
SCHEMA_VERSION = 5


def main():
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.configs.base import DECODE, ShapeConfig
    from repro.core import measure as MM
    from repro.core import predictor as PR
    from repro.core import profiler as PF
    from repro.models import init_params
    from repro.search import execplan as XP
    from repro.search import space as SP
    from repro.serving import (BlockAllocator, Engine, FaultPlan,
                               LadderConfig, OnlineLengthStats, leak_check,
                               length_stats, survivor_mismatches,
                               synthetic_trace, trace_context)
    from repro.serving.executor import JaxExecutor, PagedJaxExecutor
    from repro.serving.quality import token_agreement

    cfg = get_config(ARCH).reduced()
    # mostly-short traffic with a long-generation tail: the mix where
    # worst-case ring slots waste the most (every short request still pays
    # max-context bytes) and where lane compaction matters (the tail drains
    # at low occupancy)
    trace = synthetic_trace(12, vocab_size=cfg.vocab_size, seed=TRACE_SEED,
                            prompt_lens=(4, 8), gen_lens=(4, 4, 8, 248),
                            mean_interarrival=0.5)
    context = trace_context(trace)
    shape = ShapeConfig("bench_serve", DECODE, context, LANE_CAP)
    mesh_shape = {"data": 1, "model": 1}
    sim = MM.SimulatedMeasurer(mesh_shape)
    cls = PF.classify_workload(cfg, shape, None, n_points=2, base_seq=64,
                               measurer=sim)
    seq_lens = [len(r.prompt) + r.max_new - 1 for r in trace]

    def req(n):
        sh = dataclasses.replace(shape, global_batch=n)
        return PR.predict(cfg, sh, PR.MemoryPlan(), cls,
                          mesh_shape).capacity_bytes

    def pinned(kv_blocks):
        return SP.serving_space(cfg, shape, max_devices=1, data=(1,),
                                model=(1,), kv_blocks=kv_blocks)

    def build(splan, mode):
        n_slots = splan.slots(cap=min(LANE_CAP, len(trace)))
        if mode == "ring":
            return (JaxExecutor(params, cfg, n_slots=n_slots,
                                context=context), None, n_slots, 0)
        n_blocks = splan.pool_blocks(n_slots, context)
        compact = mode == "paged_compact"
        # paged_chunked: prompts split into kv_block-sized chunks — the
        # prompt buckets (4, 8) exceed kv_block=4, so chunking actually
        # fires (chunk_calls > 0 is asserted below)
        chunk = (2 * splan.kv_block if compact
                 else splan.kv_block if mode == "paged_chunked" else 0)
        ex = PagedJaxExecutor(params, cfg, n_lanes=n_slots,
                              n_blocks=n_blocks, kv_block=splan.kv_block,
                              context=context, compact=compact, chunk=chunk)
        return ex, BlockAllocator(n_blocks, splan.kv_block), n_slots, chunk

    def cell_metrics(splan, report, allocator, n_slots, wall, e_blocks=None,
                     block_bytes=0.0, agreement=None):
        """One benchmark cell; shared by the frontier, overload, and
        bending sweeps. `e_blocks` (expected blocks/seq) prices the
        predicted peak: min(pool, ceil(n_slots * E[blocks/seq])) — the
        calibration-loop groundwork the delta column tracks.
        `block_bytes` (per-device bytes of one paged block under the
        cell's plan) prices bytes-per-admitted-token; `agreement` is the
        cell's MEASURED token-agreement report vs greedy_generate."""
        widths = (report.decode_lane_tokens / report.decode_ticks
                  if report.decode_ticks else 0.0)
        predicted = 0
        if allocator is not None and e_blocks is not None:
            predicted = min(allocator.n_blocks,
                            int(-(-(n_slots * e_blocks) // 1)))
        bpt = (block_bytes * report.peak_blocks / report.generated_tokens
               if block_bytes and report.generated_tokens else 0.0)
        return {
            "capacity": splan.capacity,
            "n_slots": n_slots,
            "kv_block": splan.kv_block,
            "blocks": (allocator.n_blocks if allocator else 0),
            "peak_blocks": report.peak_blocks,
            "predicted_peak_blocks": predicted,
            "peak_blocks_delta": (report.peak_blocks - predicted
                                  if predicted else 0),
            "max_concurrent": report.max_concurrent,
            "concurrency_per_gib": (splan.capacity
                                    / (splan.hbm_budget / 2**30)),
            "tokens": report.generated_tokens,
            "ticks": report.ticks,
            "tokens_per_tick": report.throughput(),
            "tokens_per_s": report.generated_tokens / wall,
            "mean_latency_ticks": report.mean_latency(),
            "latency_ticks": report.latency_percentiles(),
            "ttft_ticks": report.ttft_percentiles(),
            "mean_ttft_ticks": report.mean_ttft(),
            "occupancy": report.occupancy(),
            "mean_decode_width": widths,
            "chunk_calls": report.chunk_calls,
            "prefill_calls": report.prefill_calls,
            "prefill_tokens": report.prefill_tokens,
            "prefill_tokens_per_tick": report.prefill_throughput(),
            "evictions": report.evictions,
            "block_drops": report.block_drops,
            "kv_quant": splan.execution.plan.kv_quant,
            "kv_retain": splan.execution.plan.kv_retain,
            "predicted_agreement": splan.agreement,
            "bytes_per_admitted_token": bpt,
            "agreement": (agreement.agreement if agreement else None),
            "requests_exact": (sum(1 for d in agreement.first_divergence
                                   if d < 0) if agreement else None),
        }

    params = init_params(jax.random.PRNGKey(0), cfg)
    e_blocks_by_kv = {}

    def e_blocks(kv_block, lens=None):
        lens = lens if lens is not None else seq_lens
        key = (kv_block, len(lens))
        if key not in e_blocks_by_kv:
            e_blocks_by_kv[key] = (sum(-(-s // kv_block) for s in lens)
                                   / len(lens))
        return e_blocks_by_kv[key]

    frontier = []
    refs = {}                    # greedy references, shared across budgets
    for k in RING_SLOT_BUDGETS:
        budget = (req(k) + req(k + 1)) / 2
        _, ring = XP.plan_serving(cfg, shape, n_devices=1, hbm_budget=budget,
                                  cls=cls, space=pinned((0,)))
        _, paged = XP.plan_serving(cfg, shape, n_devices=1, hbm_budget=budget,
                                   cls=cls, space=pinned((4, 8, 16)),
                                   kv="paged", seq_lens=seq_lens)
        _, pcomp = XP.plan_serving(cfg, shape, n_devices=1, hbm_budget=budget,
                                   cls=cls, space=pinned((4, 8, 16)),
                                   kv="paged", seq_lens=seq_lens,
                                   compact=True)
        cells = {}
        tokens = {}
        for mode, splan in (("ring", ring), ("paged", paged),
                            ("paged_compact", pcomp),
                            ("paged_chunked", paged)):
            # warm run pays every compile; the timed run measures serving
            executor, allocator, n_slots, chunk = build(splan, mode)
            Engine(executor, n_slots, allocator=allocator,
                   chunk_prefill=chunk).run(trace)
            compiles = executor.compile_counts()
            executor, allocator, n_slots, chunk = build(splan, mode)
            engine = Engine(executor, n_slots, allocator=allocator,
                            chunk_prefill=chunk)
            t0 = time.perf_counter()
            report = engine.run(trace)
            wall = time.perf_counter() - t0
            tokens[mode] = [list(c.tokens) for c in report.completions]
            if allocator is not None:
                # worst-case reservations: actual usage never exceeds the
                # ledger's commitment (the deadlock-freedom invariant)
                assert report.peak_blocks <= allocator.peak_committed, mode
            agree = token_agreement(params, cfg, trace, report,
                                    context=context, ref_cache=refs)
            cells[mode] = cell_metrics(
                splan, report, allocator, n_slots, wall,
                e_blocks=(e_blocks(splan.kv_block) if allocator else None),
                block_bytes=(PR.kv_block_bytes_per_device(
                    cfg, shape, splan.execution.plan, mesh_shape)
                    if allocator else 0.0),
                agreement=agree)
            if agree.agreement < 1.0:        # exact cells must stay exact
                raise SystemExit(f"budget@{k}/{mode}: exact engine drifted "
                                 f"from greedy_generate: {agree.describe()}")
            cells[mode]["compiles"] = compiles
            emit(f"serve.{mode}.b{k}.{ARCH}", wall * 1e6,
                 f"concurrent={report.max_concurrent};"
                 f"tokens_per_s={cells[mode]['tokens_per_s']:.0f};"
                 f"mean_latency={report.mean_latency():.1f};"
                 f"occupancy={report.occupancy():.3f};"
                 f"mean_width={cells[mode]['mean_decode_width']:.1f}")
        if cells["paged_chunked"]["chunk_calls"] <= 0:
            raise SystemExit(f"budget@{k}: the chunked column never chunked")
        same = (tokens["ring"] == tokens["paged"] == tokens["paged_compact"]
                == tokens["paged_chunked"])
        ratio = (cells["paged_compact"]["max_concurrent"]
                 / max(cells["ring"]["max_concurrent"], 1))
        speed = (cells["paged_compact"]["tokens_per_s"]
                 / cells["ring"]["tokens_per_s"])
        frontier.append({
            "ring_slots": k,
            "budget_bytes": budget,
            "token_identical": bool(same),
            "concurrency_ratio": ratio,
            "tokens_per_s_ratio": speed,
            **cells,
        })
        emit(f"serve.frontier.b{k}.{ARCH}", 0.0,
             f"compact_vs_ring_concurrency={ratio:.1f}x;"
             f"compact_vs_ring_tokens_per_s={speed:.2f}x;"
             f"token_identical={same}")
        if not same:
            raise SystemExit(f"budget@{k}: token streams diverged")
    tight = frontier[0]
    if tight["tokens_per_s_ratio"] < 1.0:
        raise SystemExit("tightest budget: paged+compaction reached only "
                         f"{tight['tokens_per_s_ratio']:.2f}x ring tokens/s")
    if tight["concurrency_ratio"] < 4.0:
        raise SystemExit("tightest budget: paged+compaction admitted only "
                         f"{tight['concurrency_ratio']:.1f}x ring "
                         "concurrency")

    # -- overload: optimistic admission + prefix sharing vs worst case ------
    # Burst arrivals (everything at tick 0) over a shared 16-token system
    # prompt, with a long-generation tail: worst-case reservations leave
    # most of the pool promised-but-idle, and every request re-pays the
    # prefix. The acceptance pin: optimistic+prefix admits >= 1.5x the
    # worst-case concurrency under the SAME budget, token-identically.
    otrace = synthetic_trace(24, vocab_size=cfg.vocab_size, seed=TRACE_SEED,
                             prompt_lens=(4, 8), gen_lens=(4, 8, 8, 64),
                             mean_interarrival=0.0, prefix_len=16)
    ocontext = trace_context(otrace)
    oshape = dataclasses.replace(shape, seq_len=ocontext,
                                 global_batch=OVERLOAD_LANE_CAP)
    olens = [len(r.prompt) + r.max_new - 1 for r in otrace]
    # tight enough that worst-case planning can only afford ~7 lanes while
    # expected-occupancy planning fills the 12-lane cap — admission policy,
    # not lane count, is what the section measures
    obudget = (req(2) + req(3)) / 2
    ostats = length_stats(otrace)
    _, wplan = XP.plan_serving(cfg, oshape, n_devices=1, hbm_budget=obudget,
                               cls=cls, space=pinned((4, 8, 16)), kv="paged",
                               seq_lens=olens, admission="worst")
    _, oplan = XP.plan_serving(cfg, oshape, n_devices=1, hbm_budget=obudget,
                               cls=cls, space=pinned((4, 8, 16)), kv="paged",
                               seq_lens=olens, admission="optimistic",
                               sigma_k=1.0)

    def obuild(splan, mode):
        n_slots = splan.slots(cap=min(OVERLOAD_LANE_CAP, len(otrace)))
        n_blocks = splan.pool_blocks(n_slots, ocontext)
        chunk = 2 * splan.kv_block
        ex = PagedJaxExecutor(params, cfg, n_lanes=n_slots,
                              n_blocks=n_blocks, kv_block=splan.kv_block,
                              context=ocontext, chunk=chunk)
        alloc = BlockAllocator(n_blocks, splan.kv_block,
                               reservation=("worst" if mode == "worst"
                                            else "expected"))
        eng = Engine(ex, n_slots, allocator=alloc, chunk_prefill=chunk,
                     prefix_share=(mode == "optimistic_prefix"),
                     stats=(None if mode == "worst" else ostats),
                     sigma_k=1.0)
        return ex, alloc, eng, n_slots

    ocells = {}
    otokens = {}
    orefs = {}
    for mode, splan in (("worst", wplan), ("optimistic", oplan),
                        ("optimistic_prefix", oplan)):
        _, _, warm_eng, _ = obuild(splan, mode)
        warm_eng.run(otrace)
        ex, alloc, eng, n_slots = obuild(splan, mode)
        t0 = time.perf_counter()
        report = eng.run(otrace)
        wall = time.perf_counter() - t0
        otokens[mode] = [list(c.tokens) for c in report.completions]
        if mode == "worst":
            assert report.peak_blocks <= alloc.peak_committed
            assert report.evictions == 0     # worst mode never preempts
        oagree = token_agreement(params, cfg, otrace, report,
                                 context=ocontext, ref_cache=orefs)
        if oagree.agreement < 1.0:
            raise SystemExit(f"overload/{mode}: exact engine drifted from "
                             f"greedy_generate: {oagree.describe()}")
        ocells[mode] = cell_metrics(splan, report, alloc, n_slots, wall,
                                    e_blocks=e_blocks(splan.kv_block, olens),
                                    block_bytes=PR.kv_block_bytes_per_device(
                                        cfg, oshape, splan.execution.plan,
                                        mesh_shape),
                                    agreement=oagree)
        ocells[mode]["admission"] = splan.admission
        ocells[mode]["compiles"] = ex.compile_counts()
        emit(f"serve.overload.{mode}.{ARCH}", wall * 1e6,
             f"concurrent={report.max_concurrent};"
             f"ticks={report.ticks};evictions={report.evictions};"
             f"lat_p95={report.latency_percentiles().get('p95', 0.0):.0f}")
    osame = (otokens["worst"] == otokens["optimistic"]
             == otokens["optimistic_prefix"])
    oratio = (ocells["optimistic_prefix"]["max_concurrent"]
              / max(ocells["worst"]["max_concurrent"], 1))
    overload = {
        "requests": len(otrace),
        "context": ocontext,
        "prefix_len": 16,
        "budget_bytes": obudget,
        "lane_cap": OVERLOAD_LANE_CAP,
        "token_identical": bool(osame),
        "concurrency_ratio": oratio,
        **ocells,
    }
    emit(f"serve.overload.frontier.{ARCH}", 0.0,
         f"optimistic_prefix_vs_worst_concurrency={oratio:.1f}x;"
         f"token_identical={osame}")
    if not osame:
        raise SystemExit("overload: token streams diverged")
    if oratio < 1.5:
        raise SystemExit("overload: optimistic+prefix admitted only "
                         f"{oratio:.2f}x worst-case concurrency")

    # -- capacity bending: quantized blocks + retention at the tightest -----
    # budget. A serving-class head width (head_dim=128; the smoke config's
    # 16 would let the per-position scale stripes eat most of the int8 win)
    # and a burst of more requests than the pool can hold exactly: the
    # measured concurrency IS the admission capacity, and every lossy cell
    # reports what the extra lanes cost in measured token agreement. Params
    # stay bf16: the coarser bf16 rounding absorbs batched-vs-single matmul
    # tiling noise, so the exact paged cell reproduces greedy_generate
    # token-for-token (fp32 params leak that noise into argmax near-ties
    # and break the fp pin). d_model stays narrow so per-lane decode
    # transients don't dilute the codec's byte ratio below the admission
    # win.
    bcfg = dataclasses.replace(cfg, head_dim=128)
    bparams = init_params(jax.random.PRNGKey(4), bcfg)
    btrace = synthetic_trace(24, vocab_size=bcfg.vocab_size, seed=TRACE_SEED,
                             prompt_lens=(8, 16), gen_lens=(24, 24, 56, 120),
                             mean_interarrival=0.0)
    bcontext = trace_context(btrace)
    bshape = ShapeConfig("bench_bend", DECODE, bcontext, BEND_LANE_CAP)
    blens = [len(r.prompt) + r.max_new - 1 for r in btrace]
    bsim = MM.SimulatedMeasurer(mesh_shape)
    bcls = PF.classify_workload(bcfg, bshape, None, n_points=2, base_seq=64,
                                measurer=bsim)

    def breq(n):
        sh = dataclasses.replace(bshape, global_batch=n)
        return PR.predict(bcfg, sh, PR.MemoryPlan(), bcls,
                          mesh_shape).capacity_bytes

    # just above the 2-worst-case-ring floor: the fp pool is block-starved,
    # so every byte the codec saves converts directly into admitted lanes
    bbudget = breq(2) + 0.05 * (breq(3) - breq(2))

    def bspace(quant, retain):
        return SP.serving_space(bcfg, bshape, max_devices=1, data=(1,),
                                model=(1,), kv_blocks=(8, 16),
                                kv_quants=(quant,), kv_retains=(retain,))

    bcells = {}
    brefs = {}
    for name, quant, retain in (("fp", "none", 0), ("int8", "int8", 0),
                                ("int4", "int4", 0),
                                ("int8_retain", "int8", 2)):
        _, splan = XP.plan_serving(bcfg, bshape, n_devices=1,
                                   hbm_budget=bbudget, cls=bcls,
                                   space=bspace(quant, retain), kv="paged",
                                   seq_lens=blens)
        n_slots = splan.slots(cap=min(BEND_LANE_CAP, len(btrace)))
        n_blocks = splan.pool_blocks(n_slots, bcontext)

        def bbuild():
            ex = PagedJaxExecutor(bparams, bcfg, n_lanes=n_slots,
                                  n_blocks=n_blocks, kv_block=splan.kv_block,
                                  context=bcontext, kv_quant=quant,
                                  kv_retain=retain)
            alloc = BlockAllocator(n_blocks, splan.kv_block)
            eng = Engine(ex, n_slots, allocator=alloc, kv_retain=retain)
            return ex, alloc, eng

        _, _, warm = bbuild()
        warm.run(btrace)
        ex, alloc, eng = bbuild()
        t0 = time.perf_counter()
        report = eng.run(btrace)
        wall = time.perf_counter() - t0
        agree = token_agreement(bparams, bcfg, btrace, report,
                                context=bcontext, ref_cache=brefs)
        bcells[name] = cell_metrics(
            splan, report, alloc, n_slots, wall,
            e_blocks=e_blocks(splan.kv_block, blens),
            block_bytes=PR.kv_block_bytes_per_device(
                bcfg, bshape, splan.execution.plan, mesh_shape),
            agreement=agree)
        bcells[name]["compiles"] = ex.compile_counts()
        emit(f"serve.bend.{name}.{ARCH}", wall * 1e6,
             f"concurrent={report.max_concurrent};"
             f"agreement={agree.agreement:.4f};"
             f"bytes_per_token={bcells[name]['bytes_per_admitted_token']:.0f};"
             f"block_drops={report.block_drops}")
    bratio = (bcells["int8"]["max_concurrent"]
              / max(bcells["fp"]["max_concurrent"], 1))
    bending = {
        "requests": len(btrace),
        "context": bcontext,
        "head_dim": bcfg.head_dim,
        "budget_bytes": bbudget,
        "lane_cap": BEND_LANE_CAP,
        "int8_concurrency_ratio": bratio,
        "int8_agreement": bcells["int8"]["agreement"],
        **bcells,
    }
    emit(f"serve.bend.frontier.{ARCH}", 0.0,
         f"int8_vs_fp_concurrency={bratio:.2f}x;"
         f"int8_agreement={bcells['int8']['agreement']:.4f};"
         f"int4_agreement={bcells['int4']['agreement']:.4f};"
         f"retain_agreement={bcells['int8_retain']['agreement']:.4f}")
    if bcells["fp"]["agreement"] < 1.0:
        raise SystemExit("bending: the fp paged cell must match "
                         "greedy_generate exactly")
    if bratio < 1.8:
        raise SystemExit(f"bending: int8 blocks admitted only {bratio:.2f}x "
                         "fp paged concurrency (pin: >= 1.8x)")
    if bcells["int8"]["agreement"] < 0.99:
        raise SystemExit("bending: int8 measured agreement "
                         f"{bcells['int8']['agreement']:.4f} < 0.99")

    # -- prefill-bound: the prefill transient as a priced capacity term -----
    # Long prompts, short generations, burst arrivals: ticks are dominated
    # by chunked prefill, so the transient the planner must hold back is
    # the PREFILL tick's, not the decode tick's. Each budget is planned
    # twice — charging the tiled flash-prefill kernel's O(tokens x d)
    # working set vs the dense jnp fallback's O(tokens x context) score
    # matrix — and replayed through a token-budgeted engine
    # (prefill_budget=32 over chunk=8: four chunk grants per tick,
    # fair-shared). The pin: at the tightest budget the tiled plan admits
    # >= 1.3x the dense lanes with lower mean TTFT; at the loose budget
    # the plans converge (the term stops binding) — token-identical
    # everywhere, because the budget changes WHEN chunks land, never WHAT
    # tokens emerge.
    ptrace = synthetic_trace(12, vocab_size=cfg.vocab_size, seed=TRACE_SEED,
                             prompt_lens=(32, 64), gen_lens=(4, 8),
                             mean_interarrival=0.0)
    pcontext = trace_context(ptrace)
    pshape = ShapeConfig("bench_prefill", DECODE, pcontext, PREFILL_LANE_CAP)
    plens = [len(r.prompt) + r.max_new - 1 for r in ptrace]
    psim = MM.SimulatedMeasurer(mesh_shape)
    pcls = PF.classify_workload(cfg, pshape, None, n_points=2, base_seq=64,
                                measurer=psim)
    prompt_total = sum(len(r.prompt) for r in ptrace)

    def preq(n):
        sh = dataclasses.replace(pshape, global_batch=n)
        return PR.predict(cfg, sh, PR.MemoryPlan(), pcls,
                          mesh_shape).capacity_bytes

    def pspace():
        return SP.serving_space(cfg, pshape, max_devices=1, data=(1,),
                                model=(1,), kv_blocks=(4, 8))

    prefill_rows = []
    ptokens_all = {}
    prefs = {}
    for tag, pbudget in (("tight", (preq(2) + preq(3)) / 2),
                         ("loose", (preq(3) + preq(4)) / 2)):
        pcells = {}
        for kern in ("tiled", "dense"):
            _, splan = XP.plan_serving(cfg, pshape, n_devices=1,
                                       hbm_budget=pbudget, cls=pcls,
                                       space=pspace(), kv="paged",
                                       seq_lens=plens,
                                       prefill_budget=PREFILL_BUDGET_TOKENS,
                                       prefill_kernel=kern,
                                       chunk=PREFILL_CHUNK)
            n_slots = splan.slots(cap=min(PREFILL_LANE_CAP, len(ptrace)))
            n_blocks = splan.pool_blocks(n_slots, pcontext)

            def pbuild():
                ex = PagedJaxExecutor(params, cfg, n_lanes=n_slots,
                                      n_blocks=n_blocks,
                                      kv_block=splan.kv_block,
                                      context=pcontext, chunk=PREFILL_CHUNK)
                alloc = BlockAllocator(n_blocks, splan.kv_block)
                eng = Engine(ex, n_slots, allocator=alloc,
                             chunk_prefill=PREFILL_CHUNK,
                             prefill_budget=splan.prefill_budget)
                return ex, alloc, eng

            _, _, warm = pbuild()
            warm.run(ptrace)
            ex, alloc, eng = pbuild()
            t0 = time.perf_counter()
            report = eng.run(ptrace)
            wall = time.perf_counter() - t0
            ptokens_all[(tag, kern)] = [list(c.tokens)
                                        for c in report.completions]
            if report.chunk_calls <= 0:
                raise SystemExit(f"prefill/{tag}/{kern}: never chunked")
            if report.prefill_tokens != prompt_total:
                raise SystemExit(f"prefill/{tag}/{kern}: accounted "
                                 f"{report.prefill_tokens} prefill tokens, "
                                 f"trace holds {prompt_total}")
            agree = token_agreement(params, cfg, ptrace, report,
                                    context=pcontext, ref_cache=prefs)
            if agree.agreement < 1.0:
                raise SystemExit(f"prefill/{tag}/{kern}: exact engine "
                                 "drifted from greedy_generate: "
                                 f"{agree.describe()}")
            pcells[kern] = cell_metrics(
                splan, report, alloc, n_slots, wall,
                e_blocks=e_blocks(splan.kv_block, plens),
                block_bytes=PR.kv_block_bytes_per_device(
                    cfg, pshape, splan.execution.plan, mesh_shape),
                agreement=agree)
            pcells[kern]["prefill_budget"] = splan.prefill_budget
            pcells[kern]["prefill_kernel"] = kern
            pcells[kern]["compiles"] = ex.compile_counts()
            emit(f"serve.prefill.{tag}.{kern}.{ARCH}", wall * 1e6,
                 f"lanes={n_slots};mean_ttft={report.mean_ttft():.1f};"
                 f"prefill_tps={report.prefill_throughput():.2f};"
                 f"chunk_calls={report.chunk_calls}")
        pratio = (pcells["tiled"]["n_slots"]
                  / max(pcells["dense"]["n_slots"], 1))
        prefill_rows.append({
            "budget": tag,
            "budget_bytes": pbudget,
            "lane_ratio": pratio,
            "token_identical": bool(ptokens_all[(tag, "tiled")]
                                    == ptokens_all[(tag, "dense")]),
            **pcells,
        })
        emit(f"serve.prefill.frontier.{tag}.{ARCH}", 0.0,
             f"tiled_vs_dense_lanes={pratio:.1f}x;"
             f"tiled_ttft={pcells['tiled']['mean_ttft_ticks']:.1f};"
             f"dense_ttft={pcells['dense']['mean_ttft_ticks']:.1f}")
    if len({tuple(map(tuple, t)) for t in ptokens_all.values()}) != 1:
        raise SystemExit("prefill: token streams diverged across plans")
    ptight = prefill_rows[0]
    if ptight["lane_ratio"] < 1.3:
        raise SystemExit("prefill: at the tightest budget the tiled plan "
                         f"admitted only {ptight['lane_ratio']:.2f}x the "
                         "dense-plan lanes (pin: >= 1.3x)")
    if (ptight["tiled"]["mean_ttft_ticks"]
            >= ptight["dense"]["mean_ttft_ticks"]):
        raise SystemExit("prefill: the tiled plan's extra lanes must lower "
                         "mean TTFT at the tightest budget "
                         f"({ptight['tiled']['mean_ttft_ticks']:.1f} vs "
                         f"{ptight['dense']['mean_ttft_ticks']:.1f})")
    prefill_bound = {
        "requests": len(ptrace),
        "context": pcontext,
        "lane_cap": PREFILL_LANE_CAP,
        "prefill_budget": PREFILL_BUDGET_TOKENS,
        "chunk": PREFILL_CHUNK,
        "tight_lane_ratio": ptight["lane_ratio"],
        "rows": prefill_rows,
    }

    # -- degradation: the ladder under a 25% mid-run budget shrink ----------
    # The capacity model was WRONG mid-flight (a co-located tenant claimed
    # a quarter of the pool): free blocks retire immediately, live blocks
    # become retirement debt collected as lanes drain, and the degradation
    # ladder works the committed-over-pool overhang off (tighten prefill ->
    # SLO-ordered eviction -> shedding) instead of deadlocking. The pins:
    # goodput (completed tokens/tick) stays >= 0.8x fault-free, the
    # SHRUNKEN ledger leak-checks clean, and every completion is
    # token-identical to the fault-free replay — the ladder trades
    # latency, never correctness.
    dtrace = synthetic_trace(16, vocab_size=cfg.vocab_size, seed=TRACE_SEED,
                             prompt_lens=(4, 8), gen_lens=(8, 16, 24),
                             mean_interarrival=0.5)
    dcontext = trace_context(dtrace)
    dshape = dataclasses.replace(shape, seq_len=dcontext)
    dlens = [len(r.prompt) + r.max_new - 1 for r in dtrace]
    dbudget = (req(3) + req(4)) / 2
    dstats = length_stats(dtrace)
    _, dplan = XP.plan_serving(cfg, dshape, n_devices=1, hbm_budget=dbudget,
                               cls=cls, space=pinned((4, 8, 16)), kv="paged",
                               seq_lens=dlens, admission="optimistic",
                               sigma_k=1.0)
    dn_slots = dplan.slots(cap=min(LANE_CAP, len(dtrace)))
    dn_blocks = dplan.pool_blocks(dn_slots, dcontext)
    dchunk = dplan.kv_block

    def dbuild(faults=None, ladder=None):
        ex = PagedJaxExecutor(params, cfg, n_lanes=dn_slots,
                              n_blocks=dn_blocks, kv_block=dplan.kv_block,
                              context=dcontext, chunk=dchunk)
        alloc = BlockAllocator(dn_blocks, dplan.kv_block,
                               reservation="expected")
        eng = Engine(ex, dn_slots, allocator=alloc, chunk_prefill=dchunk,
                     stats=OnlineLengthStats(base=dstats), sigma_k=1.0,
                     faults=faults, ladder=ladder, audit="strict")
        return ex, alloc, eng

    _, _, dwarm = dbuild()
    dwarm.run(dtrace)
    dex, dalloc, deng = dbuild()
    t0 = time.perf_counter()
    dff = deng.run(dtrace)
    dwall_ff = time.perf_counter() - t0
    shrink_tick = max(2, dff.ticks // 3)
    dfaults = FaultPlan(seed=TRACE_SEED,
                        shrinks=((shrink_tick, 0.25),))
    gex, galloc, geng = dbuild(faults=dfaults,
                               ladder=LadderConfig(patience=1, high=0.9))
    t0 = time.perf_counter()
    dgr = geng.run(dtrace)
    dwall_dg = time.perf_counter() - t0
    dproblems = leak_check(galloc) + survivor_mismatches(dgr, dff)
    dratio = (dgr.throughput() / dff.throughput()
              if dff.throughput() else 0.0)
    dcells = {}
    for name, rep, al, wl in (("fault_free", dff, dalloc, dwall_ff),
                              ("shrink_ladder", dgr, galloc, dwall_dg)):
        dcells[name] = cell_metrics(dplan, rep, al, dn_slots, wl,
                                    e_blocks=e_blocks(dplan.kv_block, dlens),
                                    block_bytes=PR.kv_block_bytes_per_device(
                                        cfg, dshape, dplan.execution.plan,
                                        mesh_shape))
        dcells[name].update({
            "shrunk_blocks": rep.shrunk_blocks,
            "cancelled": len(rep.cancellations),
            "audits": rep.audits,
            "max_rung": (rep.degradation or {}).get("max_rung_name",
                                                    "normal"),
            "rung_ticks": (rep.degradation or {}).get("rung_ticks", {}),
        })
    degradation = {
        "requests": len(dtrace),
        "context": dcontext,
        "budget_bytes": dbudget,
        "shrink_tick": shrink_tick,
        "shrink_frac": 0.25,
        "goodput_ratio": dratio,
        "survivors_identical": not dproblems,
        **dcells,
    }
    emit(f"serve.degradation.{ARCH}", dwall_dg * 1e6,
         f"goodput_ratio={dratio:.2f}x;"
         f"shrunk={dgr.shrunk_blocks};"
         f"max_rung={dcells['shrink_ladder']['max_rung']};"
         f"survivors_identical={not dproblems}")
    if dproblems:
        raise SystemExit("degradation: " + "; ".join(dproblems))
    if dgr.shrunk_blocks <= 0:
        raise SystemExit("degradation: the shrink never landed "
                         f"(tick {shrink_tick}, run {dgr.ticks} ticks)")
    if dratio < 0.8:
        raise SystemExit(f"degradation: goodput under a 25% shrink fell to "
                         f"{dratio:.2f}x fault-free (pin: >= 0.8x)")

    out = {
        "schema_version": SCHEMA_VERSION,
        "arch": ARCH,
        "trace_seed": TRACE_SEED,
        "requests": len(trace),
        "context": context,
        "lane_cap": LANE_CAP,
        "frontier": frontier,
        "overload": overload,
        "bending": bending,
        "prefill_bound": prefill_bound,
        "degradation": degradation,
    }
    # schema v4: every benchmark cell carries the TTFT columns — walk the
    # whole document and refuse to write a file that silently dropped them
    def check_ttft(node, where):
        if isinstance(node, dict):
            if "capacity" in node:       # a cell_metrics cell
                for col in ("mean_ttft_ticks", "ttft_ticks",
                            "prefill_tokens", "prefill_tokens_per_tick"):
                    if col not in node:
                        raise SystemExit(f"schema v{SCHEMA_VERSION}: "
                                         f"{where} lacks the {col} column")
            for k, v in node.items():
                check_ttft(v, f"{where}.{k}")
        elif isinstance(node, list):
            for i, v in enumerate(node):
                check_ttft(v, f"{where}[{i}]")

    check_ttft(out, "BENCH_serving")
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                        "BENCH_serving.json")
    with open(os.path.normpath(path), "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    flush()


if __name__ == "__main__":
    main()
