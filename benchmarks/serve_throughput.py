"""Serving throughput: ring-slot vs paged-KV engine under the SAME HBM
budget (the PR-5 acceptance benchmark).

The budget is sized so the worst-case ring admission (every slot charged a
full max-context ring) fits only a couple of sequences; the paged planner
then re-answers the same question over a block pool with the trace's own
length distribution. Reported per engine: admitted concurrency (the
paper's capacity metric, per HBM byte), generated tokens/s wall and
tokens/tick, decode-slot occupancy, pool occupancy, and compile counts —
decode must stay ONE compile in both modes. Ring and paged token streams
are asserted identical (scheduling and memory layout must never change
outputs). Results land in BENCH_serving.json at the repo root.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit, flush

ARCH = "mistral-nemo-12b"            # pure global attention: every layer pages


def main():
    import jax

    from repro.configs import get_config
    from repro.configs.base import DECODE, ShapeConfig
    from repro.core import measure as MM
    from repro.core import predictor as PR
    from repro.core import profiler as PF
    from repro.models import init_params
    from repro.search import execplan as XP
    from repro.search import space as SP
    from repro.serving import (BlockAllocator, Engine, synthetic_trace,
                               trace_context)
    from repro.serving.executor import JaxExecutor, PagedJaxExecutor

    cfg = get_config(ARCH).reduced()
    # mostly-short traffic with a long tail: the mix where worst-case ring
    # slots waste the most (every short request still pays context bytes)
    trace = synthetic_trace(12, vocab_size=cfg.vocab_size, seed=7,
                            prompt_lens=(4, 8), gen_lens=(4, 4, 8, 248),
                            mean_interarrival=0.5)
    context = trace_context(trace)
    shape = ShapeConfig("bench_serve", DECODE, context, 8)
    mesh_shape = {"data": 1, "model": 1}
    sim = MM.SimulatedMeasurer(mesh_shape)
    cls = PF.classify_workload(cfg, shape, None, n_points=2, base_seq=64,
                               measurer=sim)
    # budget: exactly two worst-case ring slots fit (Eq. 11 headroom
    # included) — midway between the 2- and 3-slot requirements so slack
    # can't hand ring a free slot at reduced scale
    import dataclasses

    def req(n):
        sh = dataclasses.replace(shape, global_batch=n)
        return PR.predict(cfg, sh, PR.MemoryPlan(), cls,
                          mesh_shape).capacity_bytes

    budget = (req(2) + req(3)) / 2
    seq_lens = [len(r.prompt) + r.max_new - 1 for r in trace]

    def pinned(kv_blocks):
        return SP.serving_space(cfg, shape, max_devices=1, data=(1,),
                                model=(1,), kv_blocks=kv_blocks)

    _, ring = XP.plan_serving(cfg, shape, n_devices=1, hbm_budget=budget,
                              cls=cls, space=pinned((0,)))
    _, paged = XP.plan_serving(cfg, shape, n_devices=1, hbm_budget=budget,
                               cls=cls, space=pinned((4, 8, 16)),
                               kv="paged", seq_lens=seq_lens)

    params = init_params(jax.random.PRNGKey(0), cfg)
    results = {}
    for name, splan in (("ring", ring), ("paged", paged)):
        n_slots = splan.slots(cap=len(trace))
        if name == "paged":
            n_blocks = splan.pool_blocks(n_slots, context)
            executor = PagedJaxExecutor(params, cfg, n_lanes=n_slots,
                                        n_blocks=n_blocks,
                                        kv_block=splan.kv_block,
                                        context=context)
            allocator = BlockAllocator(n_blocks, splan.kv_block)
        else:
            executor = JaxExecutor(params, cfg, n_slots=n_slots,
                                   context=context)
            allocator = None
        engine = Engine(executor, n_slots, allocator=allocator)
        t0 = time.perf_counter()
        report = engine.run(trace)
        wall = time.perf_counter() - t0
        compiles = executor.compile_counts()
        results[name] = {
            "capacity": splan.capacity,
            "n_slots": n_slots,
            "kv_block": splan.kv_block,
            "blocks": (allocator.n_blocks if allocator else 0),
            "peak_blocks": report.peak_blocks,
            "max_concurrent": report.max_concurrent,
            "concurrency_per_gib": splan.capacity / (budget / 2**30),
            "tokens": report.generated_tokens,
            "ticks": report.ticks,
            "tokens_per_tick": report.throughput(),
            "tokens_per_s": report.generated_tokens / wall,
            "occupancy": report.occupancy(),
            "block_occupancy": report.block_occupancy(),
            "prefill_calls": report.prefill_calls,
            "compiles": compiles,
            "completions": [list(c.tokens) for c in report.completions],
        }
        emit(f"serve.{name}.{ARCH}", wall * 1e6,
             f"capacity={splan.capacity};concurrent={report.max_concurrent};"
             f"tokens_per_tick={report.throughput():.2f};"
             f"occupancy={report.occupancy():.3f};"
             f"decode_compiles={compiles['decode']}")

    same_tokens = (results["ring"].pop("completions")
                   == results["paged"].pop("completions"))
    ratio = (results["paged"]["max_concurrent"]
             / max(results["ring"]["max_concurrent"], 1))
    out = {
        "arch": ARCH,
        "budget_bytes": budget,
        "requests": len(trace),
        "context": context,
        "token_identical": bool(same_tokens),
        "concurrency_ratio": ratio,
        "ring": results["ring"],
        "paged": results["paged"],
    }
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), os.pardir,
                        "BENCH_serving.json")
    with open(os.path.normpath(path), "w") as f:
        json.dump(out, f, indent=2)
    emit(f"serve.ratio.{ARCH}", 0.0,
         f"paged_vs_ring_concurrency={ratio:.1f}x;"
         f"token_identical={same_tokens};"
         f"decode_compiles_equal="
         f"{results['paged']['compiles']['decode'] <= results['ring']['compiles']['decode']}")
    if not same_tokens:
        raise SystemExit("ring and paged token streams diverged")
    if ratio < 2.0:
        raise SystemExit(f"paged admitted only {ratio:.2f}x ring concurrency")
    flush()


if __name__ == "__main__":
    main()
